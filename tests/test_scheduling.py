"""Tests for the pluggable TPU scheduling-discipline subsystem.

Covers the acceptance contract of the scheduling PR:

* discipline queue mechanics (``repro.serving.scheduling``): per-tenant
  FIFO is never violated, the swap_batch fairness cap and staleness bound
  hold, priority/weighted-fair select as specified;
* FCFS stays the bitwise-pinned default -- a ``swap_batch`` spec with
  ``batch_cap=1`` cannot batch and must run the native FCFS paths;
* on a pinned swap-heavy 2-tenant mix, ``swap_batch`` measurably reduces
  DES mean latency vs FCFS and the batch-amortized analytic model
  (``queueing.swap_batch_amortization``) predicts the batched mean within
  the model_vs_sim Poisson-row error band;
* the batched plan evaluator equals the scalar objective under a batching
  discipline (the PR-1 invariant extended);
* planner co-optimization (``hill_climb(discipline_space=...)``) returns
  the FCFS plan unchanged when batching is disabled and picks a batching
  spec when it wins;
* mid-flight discipline switches conserve requests in both simulators.
"""
import math

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import hill_climb, prop_alloc
from repro.core.planner import FCFS, DisciplineSpec, Plan, TenantSpec
from repro.hw.specs import EDGE_TPU_PLATFORM
from repro.serving.controller import run_adaptive
from repro.serving.des import DiscreteEventSimulator
from repro.serving.scheduling import (
    FcfsDiscipline,
    PriorityDiscipline,
    SwapBatchDiscipline,
    WeightedFairDiscipline,
    make_discipline,
)
from repro.serving.simulator import RuntimeSimulator, simulate
from repro.serving.workload import Request, poisson_trace

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores

SWAP_BATCH8 = DisciplineSpec("swap_batch", batch_cap=8)


def tenants_for(*name_rate_pairs):
    return [TenantSpec(paper_profile(n), r) for n, r in name_rate_pairs]


def _swap_pair(rate=10.0):
    """The pinned swap-heavy mix: efficientnet+gpunet full-TPU exceed SRAM
    together (Fig. 6's alpha ~ 0.5 regime) at ~0.72 FCFS utilization."""
    return tenants_for(("efficientnet", rate), ("gpunet", rate)), Plan(
        (6, 5), (0, 0)
    )


class TestDisciplineSpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            DisciplineSpec("lifo")
        with pytest.raises(ValueError):
            DisciplineSpec("swap_batch", batch_cap=0)
        with pytest.raises(ValueError):
            DisciplineSpec("swap_batch", staleness=0.0)
        with pytest.raises(ValueError):
            DisciplineSpec("priority", weights=(-1.0,))

    def test_batches_property(self):
        assert not FCFS.batches
        assert not DisciplineSpec("swap_batch", batch_cap=1).batches
        assert SWAP_BATCH8.batches
        assert not DisciplineSpec("priority").batches

    def test_plan_carries_discipline_and_defaults_to_fcfs(self):
        plan = Plan((1,), (1,))
        assert plan.discipline == FCFS
        assert Plan((1,), (1,), SWAP_BATCH8) != plan

    def test_weights_length_mismatch_rejected_at_build(self):
        # The simulators build disciplines without validate_plan; a short
        # weights tuple must fail at construction, not with an IndexError
        # inside the first contended pop.
        short = DisciplineSpec("priority", weights=(1.0,))
        with pytest.raises(ValueError):
            make_discipline(short, 2)
        with pytest.raises(ValueError):
            make_discipline(DisciplineSpec("weighted_fair", weights=(1.0,)), 3)
        ts, plan = _swap_pair(rate=2.0)
        with pytest.raises(ValueError):
            simulate(
                ts,
                Plan(plan.partition, plan.cores, short),
                HW,
                poisson_trace([2.0, 2.0], 5.0, seed=0),
                backend="des",
            )

    def test_make_discipline_returns_none_for_fcfs_equivalents(self):
        assert make_discipline(FCFS, 2) is None
        assert make_discipline(DisciplineSpec("swap_batch", batch_cap=1), 2) is None
        assert isinstance(make_discipline(SWAP_BATCH8, 2), SwapBatchDiscipline)
        assert isinstance(
            make_discipline(DisciplineSpec("priority"), 2), PriorityDiscipline
        )
        assert isinstance(
            make_discipline(DisciplineSpec("weighted_fair"), 2),
            WeightedFairDiscipline,
        )


class TestQueueMechanics:
    """Unit tests on the discipline objects (jobs are (model,) stubs)."""

    def _drain(self, disc, run_model=None, now=0.0):
        """Pop everything, tracking the server's run state as the
        simulators do; returns the served job sequence."""
        out, run_len = [], 0
        while len(disc):
            job = disc.pop(now, run_model, run_len)
            if job[0] == run_model:
                run_len += 1
            else:
                run_model, run_len = job[0], 1
            out.append(job)
        return out

    def test_fcfs_is_global_fifo(self):
        disc = FcfsDiscipline(FCFS, 3)
        jobs = [(0, "a"), (1, "b"), (0, "c"), (2, "d"), (1, "e")]
        for j, t in zip(jobs, range(5)):
            disc.push(j, float(t))
        assert self._drain(disc) == jobs

    def test_swap_batch_extends_runs_but_never_reorders_within_tenant(self):
        disc = SwapBatchDiscipline(SWAP_BATCH8, 2)
        # Interleaved enqueue order; server currently running tenant 0.
        seq = [(1, 0), (0, 1), (1, 2), (0, 3), (1, 4), (0, 5)]
        for j, t in zip(seq, range(6)):
            disc.push(j, float(t))
        served = self._drain(disc, run_model=0)
        # Tenant 0's jobs first (run extension), then tenant 1's -- and
        # within each tenant strictly in enqueue order.
        assert served == [(0, 1), (0, 3), (0, 5), (1, 0), (1, 2), (1, 4)]

    def test_swap_batch_respects_fairness_cap(self):
        cap = 3
        disc = SwapBatchDiscipline(DisciplineSpec("swap_batch", batch_cap=cap), 2)
        disc.push((1, "head"), 0.0)  # global FCFS head, other tenant
        for k in range(6):
            disc.push((0, k), 1.0 + k)
        # Server has already served cap-1 consecutive tenant-0 jobs: one
        # more extension is allowed, then the head must be served.
        first = disc.pop(10.0, 0, cap - 1)
        assert first == (0, 0)
        second = disc.pop(10.0, 0, cap)
        assert second == (1, "head")
        # After the switch tenant 1 has nothing queued, so FCFS order
        # resumes at tenant 0's earliest remaining job.
        third = disc.pop(10.0, 1, 1)
        assert third == (0, 1)

    def test_swap_batch_head_never_overtaken_by_more_than_cap(self):
        # System-level starvation bound: however long tenant 0's backlog,
        # tenant 1's head job is served after at most batch_cap services.
        cap = 4
        disc = SwapBatchDiscipline(DisciplineSpec("swap_batch", batch_cap=cap), 2)
        disc.push((1, "head"), 0.0)
        for k in range(50):
            disc.push((0, k), 0.1 + k)
        served = self._drain(disc, run_model=0)
        assert served.index((1, "head")) <= cap

    def test_swap_batch_staleness_breaks_runs_early(self):
        spec = DisciplineSpec("swap_batch", batch_cap=8, staleness=1.0)
        disc = SwapBatchDiscipline(spec, 2)
        disc.push((1, "old"), 0.0)
        disc.push((0, "fresh"), 0.5)
        # Head has waited 5 s > staleness 1 s: the run must break even
        # though the cap would allow an extension.
        assert disc.pop(5.0, 0, 1) == (1, "old")
        # A fresh head lets the run extend.
        disc.push((1, "new"), 5.0)
        assert disc.pop(5.2, 0, 1) == (0, "fresh")

    def test_priority_orders_by_weight_then_fifo(self):
        disc = PriorityDiscipline(
            DisciplineSpec("priority", weights=(0.0, 5.0, 1.0)), 3
        )
        jobs = [(0, "a"), (2, "b"), (1, "c"), (1, "d"), (2, "e")]
        for j, t in zip(jobs, range(5)):
            disc.push(j, float(t))
        assert self._drain(disc) == [
            (1, "c"), (1, "d"), (2, "b"), (2, "e"), (0, "a")
        ]

    def test_weighted_fair_converges_to_weight_shares(self):
        disc = WeightedFairDiscipline(
            DisciplineSpec("weighted_fair", weights=(3.0, 1.0)), 2
        )
        for k in range(40):
            disc.push((0, k), float(k))
            disc.push((1, k), float(k) + 0.5)
        served, counts = [], [0, 0]
        run_model, run_len = None, 0
        for _ in range(20):
            job = disc.pop(100.0, run_model, run_len)
            run_model = job[0]
            counts[job[0]] += 1
            disc.charge(job[0], 1.0)  # unit service per job
            served.append(job)
        # 3:1 weights with equal unit services -> ~3:1 service counts.
        assert counts[0] == pytest.approx(15, abs=1)
        # Per-tenant FIFO within the interleaving.
        for i in (0, 1):
            mine = [j[1] for j in served if j[0] == i]
            assert mine == sorted(mine)

    def test_drain_rows_preserves_global_enqueue_order(self):
        disc = SwapBatchDiscipline(SWAP_BATCH8, 3)
        jobs = [(2, "a"), (0, "b"), (1, "c"), (0, "d")]
        for j, t in zip(jobs, range(4)):
            disc.push(j, float(t))
        rows = disc.drain_rows()
        assert [job for _, _, job in rows] == jobs
        assert len(disc) == 0


class TestFcfsStaysPinned:
    """A cap-1 swap_batch spec cannot batch: both simulators must take the
    native FCFS paths and reproduce the default-plan run bitwise."""

    def test_cap_one_is_bitwise_fcfs(self):
        ts, plan = _swap_pair(rate=6.0)
        trace = poisson_trace([6.0, 6.0], 300.0, seed=3)
        cap1 = Plan(plan.partition, plan.cores, DisciplineSpec("swap_batch"))
        for backend in ("des", "stepper"):
            a = simulate(ts, plan, HW, trace, backend=backend)
            b = simulate(ts, cap1, HW, trace, backend=backend)
            for x, y in zip(a.latencies, b.latencies):
                assert np.array_equal(np.asarray(x), np.asarray(y))
            assert a.misses == b.misses
            assert a.tpu_busy == b.tpu_busy

    def test_single_tenant_swap_batch_equals_fcfs(self):
        # One tenant has nothing to batch: the deferred machinery must
        # reproduce the scalar FCFS stepper's observables bitwise (same
        # service order, same per-request float ops).
        ts = tenants_for(("inceptionv4", 2.0))
        plan_f = Plan((9,), (4,))
        plan_b = Plan((9,), (4,), SWAP_BATCH8)
        trace = poisson_trace([2.0], 300.0, seed=4)
        a = simulate(ts, plan_f, HW, trace, backend="stepper")
        b = simulate(ts, plan_b, HW, trace, backend="stepper")
        assert np.array_equal(np.asarray(a.latencies[0]), np.asarray(b.latencies[0]))
        assert a.misses == b.misses and a.tpu_requests == b.tpu_requests


class TestSwapBatchSystemBehavior:
    def _run(self, spec, *, rate=10.0, duration=1500.0, backend="des"):
        ts, base = _swap_pair(rate)
        plan = Plan(base.partition, base.cores, spec)
        trace = poisson_trace([rate, rate], duration, seed=1)
        return ts, plan, simulate(ts, plan, HW, trace, backend=backend)

    def test_per_tenant_fifo_preserved(self):
        # Full-TPU routes: completion order == service order, so sorted
        # per-model arrival recordings prove the discipline never reordered
        # within a tenant.
        _, _, res = self._run(SWAP_BATCH8, duration=400.0)
        for i in range(2):
            arr = np.asarray(res.arrivals[i])
            assert arr.size > 100
            assert np.all(arr[1:] >= arr[:-1])

    def test_pinned_mix_swap_batch_beats_fcfs_and_model_predicts_it(self):
        """The acceptance row: measured amortization win + model accuracy.

        Measured on this seed: FCFS mean 89.2 ms -> swap_batch(8) 67.9 ms
        (-24%), DES-observed; the batch-amortized analytic model predicts
        72.1 ms (+6.1% of observed).  The 12% assertion band is the
        model_vs_sim Poisson-row band (the same tolerance
        tests/test_des.py grants the FCFS model on its home turf).
        """
        rates = [10.0, 10.0]
        ts, plan_f, fcfs = self._run(FCFS)
        _, plan_b, batched = self._run(SWAP_BATCH8)
        obs_f = fcfs.request_weighted_mean(rates)
        obs_b = batched.request_weighted_mean(rates)
        # Measurable amortization win (measured ~24%; assert >15%).
        assert obs_b < 0.85 * obs_f
        # Fewer swap-ins is the mechanism, not a side effect.
        for i in range(2):
            assert batched.observed_miss_rate(i) < fcfs.observed_miss_rate(i)
        # The extended analytic model predicts both means within the
        # Poisson-row band.
        pred_f = latency.predict(ts, plan_f, HW).mean_latency(ts)
        pred_b = latency.predict(ts, plan_b, HW).mean_latency(ts)
        assert pred_f == pytest.approx(obs_f, rel=0.12)
        assert pred_b == pytest.approx(obs_b, rel=0.12)
        # And the predicted ordering matches the observed one.
        assert pred_b < pred_f

    def test_heterogeneous_input_transfers_match_des(self):
        # Regression: the stepper's deferred loop once advanced to the
        # offered job's own enqueue time (arrival + input_xfer), finalizing
        # service decisions past enqueues of models with *smaller* input
        # transfers -- latent on the paper profiles (all share input_bytes)
        # but real decision-order divergence on any heterogeneous pair.
        import dataclasses

        eff = paper_profile("efficientnet")
        gpu = dataclasses.replace(
            paper_profile("gpunet"), input_bytes=15_000_000
        )
        ts = [TenantSpec(eff, 10.0), TenantSpec(gpu, 10.0)]
        plan = Plan((6, 5), (0, 0), SWAP_BATCH8)
        trace = poisson_trace([10.0, 10.0], 300.0, seed=1)
        des = simulate(ts, plan, HW, trace, backend="des")
        st = simulate(ts, plan, HW, trace, backend="stepper")
        assert des.misses == st.misses
        for i in range(2):
            d = sorted(zip(des.arrivals[i], des.latencies[i]))
            s = sorted(
                zip(
                    np.asarray(st.arrivals[i]).tolist(),
                    np.asarray(st.latencies[i]).tolist(),
                )
            )
            for (at_d, a), (at_s, b) in zip(d, s):
                assert at_d == at_s
                assert a == pytest.approx(b, rel=1e-12, abs=1e-15)

    def test_des_and_stepper_agree_under_swap_batch(self):
        rates = [10.0, 10.0]
        _, _, des = self._run(SWAP_BATCH8, duration=500.0, backend="des")
        _, _, st = self._run(SWAP_BATCH8, duration=500.0, backend="stepper")
        assert des.tpu_requests == st.tpu_requests
        for i in range(2):
            assert des.mean_latency(i) == pytest.approx(
                st.mean_latency(i), rel=0.05
            )
            assert des.observed_miss_rate(i) == pytest.approx(
                st.observed_miss_rate(i), abs=0.05
            )

    def test_amortized_objective_monotone_in_cap(self):
        ts, plan = _swap_pair()
        objs = []
        for cap in (1, 2, 4, 8, 16):
            spec = DisciplineSpec("swap_batch", batch_cap=cap)
            p = Plan(plan.partition, plan.cores, spec)
            objs.append(latency.objective(ts, p, HW))
        assert objs[0] == latency.objective(ts, plan, HW)  # cap 1 == FCFS
        for a, b in zip(objs, objs[1:]):
            assert b <= a + 1e-12  # a larger cap never predicts worse

    def test_staleness_throttled_spec_priced_near_fcfs(self):
        # Regression: the analytic model once ignored staleness, pricing a
        # throttled swap_batch spec at the full amortization win while the
        # DES (whose runs the bound keeps breaking) stayed at FCFS latency
        # -- a planner mis-commitment.  The freshness factor collapses the
        # predicted win as staleness drops below the queueing delay.
        ts, plan = _swap_pair()
        pred_fcfs = latency.predict(ts, plan, HW).mean_latency(ts)
        means = []
        for stale in (math.inf, 0.1, 0.001):
            spec = DisciplineSpec("swap_batch", batch_cap=8, staleness=stale)
            p = Plan(plan.partition, plan.cores, spec)
            means.append(latency.predict(ts, p, HW).mean_latency(ts))
        unthrottled, mild, throttled = means
        assert unthrottled < mild < throttled <= pred_fcfs
        # Tight staleness ~ FCFS (within 1%); inf keeps the full win.
        assert throttled == pytest.approx(pred_fcfs, rel=0.01)
        assert unthrottled < 0.9 * pred_fcfs
        # And the DES agrees the throttled discipline behaves like FCFS.
        rate = ts[0].rate
        trace = poisson_trace([rate, rate], 400.0, seed=1)
        obs_f = simulate(ts, plan, HW, trace, backend="des")
        obs_t = simulate(
            ts,
            Plan(
                plan.partition,
                plan.cores,
                DisciplineSpec("swap_batch", batch_cap=8, staleness=0.001),
            ),
            HW,
            trace,
            backend="des",
        )
        assert obs_t.request_weighted_mean([rate, rate]) == pytest.approx(
            obs_f.request_weighted_mean([rate, rate]), rel=0.05
        )

    def test_batch_equals_scalar_for_batching_discipline(self):
        # The PR-1 batch == scalar invariant, extended to swap_batch.
        ts, _ = _swap_pair()
        parts, cores_l, scal = [], [], []
        for p1 in range(0, 7):
            for p2 in range(0, 6):
                try:
                    k = prop_alloc(ts, [p1, p2], K_MAX)
                except ValueError:
                    continue
                parts.append([p1, p2])
                cores_l.append(list(k))
                scal.append(
                    latency.penalized_objective(
                        ts, Plan((p1, p2), k, SWAP_BATCH8), HW
                    )
                )
        batched = latency.penalized_objective_batch(
            ts, np.array(parts), np.array(cores_l), HW, discipline=SWAP_BATCH8
        )
        np.testing.assert_allclose(batched, np.array(scal), rtol=1e-9)

    def test_delta_batch_matches_full_batch_for_discipline(self):
        ts, _ = _swap_pair()
        base_p = np.array([6, 5])
        base_k = np.array([0, 0])
        parts = np.array([[5, 5], [6, 4], [4, 5], [6, 5]])
        cores = np.array([[1, 0], [0, 1], [2, 0], [0, 0]])
        full = latency.penalized_objective_batch(
            ts, parts, cores, HW, discipline=SWAP_BATCH8
        )
        delta = latency.penalized_objective_delta_batch(
            ts, base_p, base_k, parts, cores, HW, discipline=SWAP_BATCH8
        )
        np.testing.assert_allclose(delta, full, rtol=1e-9)


class TestPlannerCoOptimization:
    def test_disabled_batching_returns_fcfs_plan_unchanged(self):
        ts, _ = _swap_pair()
        base_plan, base_obj = hill_climb(ts, HW, K_MAX)
        space = (FCFS, DisciplineSpec("swap_batch", batch_cap=1))
        plan, obj = hill_climb(ts, HW, K_MAX, discipline_space=space)
        assert plan == base_plan
        assert obj == base_obj
        assert plan.discipline == FCFS

    def test_tie_breaks_to_non_batching_regardless_of_order(self):
        # On a no-swap mix (prefixes co-resident in SRAM) batching prices
        # identically but measurably hurts the simulated system: a
        # predicted tie must resolve to the FCFS-equivalent plan even when
        # the caller lists the batching spec first.
        ts = tenants_for(("mobilenetv2", 3.0), ("squeezenet", 3.0))
        base_plan, base_obj = hill_climb(ts, HW, K_MAX)
        plan, obj = hill_climb(
            ts, HW, K_MAX, discipline_space=(SWAP_BATCH8, FCFS)
        )
        assert obj == base_obj
        assert plan.discipline == FCFS
        assert plan == base_plan
        # Same for a priority spec the mean objective cannot separate from
        # FCFS: the tie must not commit the starvation-capable discipline.
        pri = DisciplineSpec("priority", weights=(1.0, 0.0))
        plan2, obj2 = hill_climb(
            ts, HW, K_MAX, discipline_space=(pri, SWAP_BATCH8, FCFS)
        )
        assert plan2.discipline == FCFS
        assert obj2 == base_obj
        # Without FCFS in the space, the first-listed non-batching spec
        # represents the (identically-priced) non-batching group.
        plan3, _ = hill_climb(ts, HW, K_MAX, discipline_space=(pri,))
        assert plan3.discipline == pri

    def test_joint_search_commits_batching_when_it_wins(self):
        ts, _ = _swap_pair()
        base_plan, base_obj = hill_climb(ts, HW, K_MAX)
        space = (
            FCFS,
            DisciplineSpec("swap_batch", batch_cap=4),
            SWAP_BATCH8,
        )
        plan, obj = hill_climb(ts, HW, K_MAX, discipline_space=space)
        # On the swap-thrashing pair amortization strictly improves the
        # predicted objective, so the joint optimum batches.
        assert plan.discipline.batches
        assert obj < base_obj

    def test_fixed_discipline_climb_carries_spec(self):
        ts, _ = _swap_pair()
        plan, _ = hill_climb(ts, HW, K_MAX, discipline=SWAP_BATCH8)
        assert plan.discipline == SWAP_BATCH8

    def test_run_adaptive_co_optimizes_discipline(self):
        profiles = [paper_profile("efficientnet"), paper_profile("gpunet")]
        trace = poisson_trace([10.0, 10.0], 150.0, seed=5)
        space = (FCFS, SWAP_BATCH8)
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            replan_period=30.0,
            initial_rates=(10.0, 10.0),
            discipline_space=space,
        )
        assert all(p.discipline in space for p in res.plans)
        assert sum(len(l) for l in res.sim.latencies) > 0
        # On this mix the joint search should commit batching at least once.
        assert any(p.discipline.batches for p in res.plans)

    def test_run_adaptive_accepts_kwargs_planner(self):
        # A **kwargs wrapper around hill_climb accepts discipline_space
        # without naming it; the support check must not reject it.
        def wrapper(*args, **kwargs):
            return hill_climb(*args, **kwargs)

        profiles = [paper_profile("mnasnet")]
        trace = poisson_trace([2.0], 40.0, seed=6)
        res = run_adaptive(
            profiles,
            trace,
            HW,
            K_MAX,
            discipline_space=(FCFS,),
            planner=wrapper,
            initial_rates=(2.0,),
        )
        assert all(p.discipline == FCFS for p in res.plans)

    def test_run_adaptive_rejects_unsupporting_planner(self):
        def naive_planner(tenants, platform, k_max):
            return hill_climb(tenants, platform, k_max)

        profiles = [paper_profile("mnasnet")]
        trace = poisson_trace([2.0], 50.0, seed=6)
        with pytest.raises(ValueError):
            run_adaptive(
                profiles,
                trace,
                HW,
                K_MAX,
                discipline_space=(FCFS,),
                planner=naive_planner,
            )


class TestMidFlightDisciplineSwitch:
    def test_des_switch_conserves_requests(self):
        profiles = [paper_profile("efficientnet"), paper_profile("gpunet")]
        plans = [
            Plan((6, 5), (0, 0)),
            Plan((6, 5), (0, 0), SWAP_BATCH8),
            Plan((6, 5), (0, 0), DisciplineSpec("priority", weights=(1.0, 0.0))),
            Plan((6, 5), (0, 0)),
        ]
        reqs = poisson_trace([8.0, 8.0], 40.0, seed=7)
        des = DiscreteEventSimulator(profiles, plans[0], HW)
        next_switch, pi = 10.0, 1
        for r in reqs:
            while r.arrival >= next_switch:
                des.advance_to(next_switch)
                des.set_plan(plans[pi % len(plans)], now=next_switch)
                pi += 1
                next_switch += 10.0
            des.offer(r)
        des.drain()
        assert sum(len(l) for l in des.latencies) == len(reqs)
        assert all(l >= 0.0 for ls in des.latencies for l in ls)

    def test_stepper_switch_conserves_requests(self):
        profiles = [paper_profile("efficientnet"), paper_profile("gpunet")]
        plans = [
            Plan((6, 5), (0, 0), SWAP_BATCH8),
            Plan((6, 5), (0, 0)),  # back to FCFS with work in flight
            Plan((6, 5), (0, 0), SWAP_BATCH8),
        ]
        reqs = poisson_trace([8.0, 8.0], 30.0, seed=8)
        sim = RuntimeSimulator(profiles, plans[0], HW)
        next_switch, pi = 10.0, 1
        for r in reqs:
            while r.arrival >= next_switch:
                sim.advance_to(next_switch)
                sim.set_plan(plans[pi % len(plans)], now=next_switch)
                pi += 1
                next_switch += 10.0
            sim.offer(r)
        sim.drain()
        assert sum(len(l) for l in sim.latencies) == len(reqs)

    def test_step_rejected_under_non_fcfs(self):
        sim = RuntimeSimulator(
            [paper_profile("mnasnet")], Plan((7,), (0,), SWAP_BATCH8), HW
        )
        with pytest.raises(ValueError):
            sim.step(Request(0, 0.0))
