"""Tests for the partitionable CNN families (paper Table II) and their
integration with the real-execution serving engine."""
import jax
import numpy as np
import pytest

from repro.core.planner import Plan
from repro.models.cnn import PAPER_CNN_SPECS, build_executable
from repro.serving.engine import ServingEngine


def test_specs_match_table_ii_partition_points():
    expected = {
        "squeezenet": 2,
        "mobilenetv2": 5,
        "efficientnet": 6,
        "mnasnet": 7,
        "gpunet": 5,
        "densenet201": 7,
        "resnet50v2": 8,
        "xception": 11,
        "inceptionv4": 11,
    }
    for name, pp in expected.items():
        assert len(PAPER_CNN_SPECS[name].stage_channels) == pp, name


@pytest.mark.parametrize("name", ["mobilenetv2", "squeezenet"])
def test_cnn_forward_shapes(name):
    model = build_executable(PAPER_CNN_SPECS[name], seed=0)
    x = model.make_input(0)
    for seg in model.segments:
        x = seg(x)
    x = np.asarray(x)
    assert np.all(np.isfinite(x))
    assert x.shape[-1] == PAPER_CNN_SPECS[name].stage_channels[-1]


def test_partitioned_equals_unpartitioned():
    model = build_executable(PAPER_CNN_SPECS["mobilenetv2"], seed=1)
    x0 = model.make_input(7)
    full = x0
    for seg in model.segments:
        full = seg(full)
    for p in range(len(model.segments) + 1):
        y = x0
        for seg in model.segments[:p]:
            y = seg(y)
        for seg in model.segments[p:]:
            y = seg(y)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(full), rtol=1e-5, atol=1e-5
        )


def test_engine_runs_cnn_mix():
    models = [
        build_executable(PAPER_CNN_SPECS["mobilenetv2"], seed=0),
        build_executable(PAPER_CNN_SPECS["squeezenet"], seed=1),
    ]
    plan = Plan((3, 1), (1, 1))
    eng = ServingEngine(models, plan, k_max=4)
    try:
        for i in range(2):
            for s in range(3):
                eng.submit(i, models[i].make_input(s))
        done = eng.drain(timeout=60.0)
        assert len(done) == 6
        for c in done:
            assert np.all(np.isfinite(np.asarray(c.output)))
    finally:
        eng.shutdown()
