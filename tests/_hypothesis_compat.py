"""Offline-friendly stand-in for ``hypothesis``.

The real ``hypothesis`` package is used whenever it is importable.  When it
is not (air-gapped CI, minimal containers), this module degrades ``given``/
``settings``/``st`` to a deterministic example-based runner: each decorated
test runs against a fixed pseudo-random set of drawn examples (seeded from
the test's qualified name, so runs are reproducible and failures stable),
with range endpoints always included in the first draws.

Only the strategy surface the suite uses is implemented: ``floats``,
``integers``, ``sampled_from``, ``lists``, ``tuples``, and ``data``.

Usage in test modules::

    from tests._hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """Base: a strategy draws one example from an RNG."""

        def draw(self, rng: random.Random):
            raise NotImplementedError

        def edge_examples(self) -> list:
            """Deterministic boundary examples tried before random draws."""
            return []

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0, **_ignored):
            self.lo, self.hi = float(min_value), float(max_value)

        def draw(self, rng):
            return rng.uniform(self.lo, self.hi)

        def edge_examples(self):
            return [self.lo, self.hi]

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=100, **_ignored):
            self.lo, self.hi = int(min_value), int(max_value)

        def draw(self, rng):
            return rng.randint(self.lo, self.hi)

        def edge_examples(self):
            return [self.lo, self.hi]

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def draw(self, rng):
            return rng.choice(self.elements)

        def edge_examples(self):
            return self.elements[:1]

    class _Lists(_Strategy):
        def __init__(self, elements, *, min_size=0, max_size=10, **_ignored):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def draw(self, rng):
            size = rng.randint(self.min_size, self.max_size)
            return [self.elements.draw(rng) for _ in range(size)]

    class _Tuples(_Strategy):
        def __init__(self, *elements):
            self.elements = elements

        def draw(self, rng):
            return tuple(e.draw(rng) for e in self.elements)

    class _DataObject:
        """Interactive draws inside a test body (st.data())."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _DataStrategy(_Strategy):
        def draw(self, rng):
            return _DataObject(rng)

    class _St:
        floats = staticmethod(_Floats)
        integers = staticmethod(_Integers)
        sampled_from = staticmethod(_SampledFrom)
        lists = staticmethod(_Lists)
        tuples = staticmethod(_Tuples)
        data = staticmethod(_DataStrategy)

    st = _St()

    def settings(**kwargs):
        """Record max_examples on the function; everything else is ignored."""

        def decorate(fn):
            if "max_examples" in kwargs:
                fn._compat_max_examples = kwargs["max_examples"]
            return fn

        return decorate

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*call_args, **call_kwargs):
                max_examples = getattr(
                    wrapper,
                    "_compat_max_examples",
                    getattr(fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                # Boundary pass: hold every strategy at one of its edge
                # examples simultaneously (hypothesis shrinks toward these).
                edge_sets = [s.edge_examples() for s in arg_strategies] + [
                    s.edge_examples() for s in kw_strategies.values()
                ]
                n_edge_rounds = max((len(e) for e in edge_sets), default=0)
                for i in range(n_edge_rounds + max_examples):
                    drawn_args, drawn_kwargs = [], {}
                    for j, s in enumerate(arg_strategies):
                        edges = edge_sets[j]
                        if i < n_edge_rounds and edges:
                            drawn_args.append(edges[min(i, len(edges) - 1)])
                        else:
                            drawn_args.append(s.draw(rng))
                    for j, (name, s) in enumerate(kw_strategies.items()):
                        edges = edge_sets[len(arg_strategies) + j]
                        if i < n_edge_rounds and edges:
                            drawn_kwargs[name] = edges[min(i, len(edges) - 1)]
                        else:
                            drawn_kwargs[name] = s.draw(rng)
                    try:
                        fn(*call_args, *drawn_args, **drawn_kwargs)
                    except Exception as e:
                        shown = {f"arg{j}": v for j, v in enumerate(drawn_args)}
                        shown.update(drawn_kwargs)
                        raise AssertionError(
                            f"falsifying example ({fn.__qualname__}, "
                            f"round {i}): {shown!r}"
                        ) from e

            # Hide the strategy-provided parameters from pytest, which would
            # otherwise treat them as fixtures (hypothesis does the same).
            # Positional strategies fill the rightmost parameters.
            sig = inspect.signature(fn)
            params = [p for p in sig.parameters.values() if p.name not in kw_strategies]
            if arg_strategies:
                params = params[: -len(arg_strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper

        return decorate
