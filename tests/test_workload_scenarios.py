"""Property tests for the workload scenario library (via the offline
hypothesis shim): every generator yields time-sorted, in-range traces;
churn never emits requests for departed tenants; replay round-trips
through JSON bit-exactly."""
import math

import pytest
from tests._hypothesis_compat import given, settings, st

from repro.serving.workload import (
    Request,
    deterministic_trace,
    diurnal_trace,
    dynamic_trace,
    mmpp_trace,
    poisson_trace,
    RatePhase,
    tenant_churn_trace,
    trace_from_json,
    trace_to_json,
    with_service_jitter,
)


def _assert_trace_well_formed(reqs, n_models, duration):
    times = [r.arrival for r in reqs]
    assert times == sorted(times)
    for r in reqs:
        assert 0 <= r.model_idx < n_models
        assert 0.0 <= r.arrival < duration
        assert r.service_scale > 0.0


class TestGeneratorProperties:
    @given(
        rates=st.lists(st.floats(0.0, 8.0), min_size=1, max_size=4),
        duration=st.floats(10.0, 200.0),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=15, deadline=None)
    def test_poisson_well_formed(self, rates, duration, seed):
        reqs = poisson_trace(rates, duration, seed=seed)
        _assert_trace_well_formed(reqs, len(rates), duration)
        # Zero-rate models emit nothing.
        for i, lam in enumerate(rates):
            if lam == 0.0:
                assert all(r.model_idx != i for r in reqs)

    @given(
        rates=st.lists(st.floats(0.0, 5.0), min_size=1, max_size=3),
        duration=st.floats(20.0, 300.0),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=10, deadline=None)
    def test_mmpp_well_formed(self, rates, duration, seed):
        reqs = mmpp_trace(
            rates, duration, burst_factor=3.0, mean_normal=30.0,
            mean_burst=10.0, seed=seed,
        )
        _assert_trace_well_formed(reqs, len(rates), duration)

    @given(
        rates=st.lists(st.floats(0.0, 5.0), min_size=1, max_size=3),
        amplitude=st.floats(0.0, 1.0),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=10, deadline=None)
    def test_diurnal_well_formed(self, rates, amplitude, seed):
        duration = 300.0
        reqs = diurnal_trace(
            rates, duration, amplitude=amplitude, period=120.0, seed=seed
        )
        _assert_trace_well_formed(reqs, len(rates), duration)

    @given(
        rates=st.lists(st.floats(0.0, 5.0), min_size=1, max_size=3),
        duration=st.floats(10.0, 200.0),
    )
    @settings(max_examples=10, deadline=None)
    def test_deterministic_well_formed(self, rates, duration):
        reqs = deterministic_trace(rates, duration)
        _assert_trace_well_formed(reqs, len(rates), duration)
        # Every in-horizon arrival is kept: the count per model is within
        # one of duration * rate (the phase offset decides which side of
        # floor(duration * rate) it lands on; the pre-fix floor() draw
        # could silently drop the last in-horizon arrival).
        for i, lam in enumerate(rates):
            n = sum(1 for r in reqs if r.model_idx == i)
            assert abs(n - duration * lam) <= 1.0

    def test_deterministic_keeps_last_in_horizon_arrival(self):
        # Regression for the floor() over-draw bug: with lam=1, duration=10.9
        # the single stream's phase is (0+1)/(1+1) = 0.5, so arrivals sit at
        # 0.5, 1.5, ..., 10.5 -- eleven of them, but floor(10.9) = 10 draws
        # silently dropped the t=10.5 arrival.
        trace = deterministic_trace([1.0], 10.9)
        times = trace.arrival.tolist()
        assert len(times) == 11
        assert times[-1] == 10.5
        assert all(t < 10.9 for t in times)

    def test_deterministic_equal_rates_never_collide(self):
        # Per-stream phase offsets keep equal-rate streams disjoint; a
        # shared offset would make every j-th arrival a tie and queue one
        # request behind the other (breaking the zero-queueing guarantee).
        reqs = deterministic_trace([0.5, 0.5, 0.5], 100.0)
        times = [r.arrival for r in reqs]
        assert len(set(times)) == len(times)

    def test_negative_rate_rejected_everywhere(self):
        for gen in (
            lambda: poisson_trace([-1.0], 10.0),
            lambda: deterministic_trace([1.0, -0.1], 10.0),
            lambda: mmpp_trace([-2.0], 10.0),
            lambda: diurnal_trace([-0.5], 10.0),
            lambda: tenant_churn_trace([-1.0], 10.0),
        ):
            with pytest.raises(ValueError):
                gen()

    def test_parameter_validation(self):
        with pytest.raises(ValueError):
            diurnal_trace([1.0], 10.0, amplitude=1.5)
        with pytest.raises(ValueError):
            diurnal_trace([1.0], 10.0, period=0.0)
        with pytest.raises(ValueError):
            mmpp_trace([1.0], 10.0, mean_normal=0.0)
        with pytest.raises(ValueError):
            mmpp_trace([1.0], 10.0, burst_factor=-1.0)
        with pytest.raises(ValueError):
            with_service_jitter([Request(0, 0.0)], sigma=-0.5)
        with pytest.raises(ValueError):
            tenant_churn_trace([1.0], 10.0, mean_session=0.0)

    def test_poisson_hits_nominal_rate(self):
        reqs = poisson_trace([5.0], duration=2000.0, seed=1)
        assert len(reqs) / 2000.0 == pytest.approx(5.0, rel=0.05)

    def test_mmpp_mean_rate_matches_theory(self):
        # Long-run mean rate = base * (mean_n + bf * mean_b)/(mean_n + mean_b).
        reqs = mmpp_trace(
            [2.0], 20000.0, burst_factor=4.0, mean_normal=60.0,
            mean_burst=15.0, seed=2,
        )
        expected = 2.0 * (60.0 + 4.0 * 15.0) / 75.0
        assert len(reqs) / 20000.0 == pytest.approx(expected, rel=0.1)

    def test_diurnal_mean_rate_is_base_rate(self):
        # The sinusoid integrates to zero over whole periods.
        reqs = diurnal_trace(
            [3.0], 6000.0, amplitude=0.8, period=600.0, seed=3
        )
        assert len(reqs) / 6000.0 == pytest.approx(3.0, rel=0.08)


class TestChurn:
    @given(
        rates=st.lists(st.floats(0.5, 5.0), min_size=1, max_size=3),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=10, deadline=None)
    def test_requests_only_inside_sessions(self, rates, seed):
        duration = 400.0
        ct = tenant_churn_trace(
            rates, duration, mean_session=60.0, mean_absence=40.0, seed=seed
        )
        _assert_trace_well_formed(list(ct.requests), len(rates), duration)
        for r in ct.requests:
            sessions = ct.active[r.model_idx]
            assert any(a <= r.arrival < b for a, b in sessions), (
                f"request at {r.arrival} outside every session of model "
                f"{r.model_idx}: {sessions}"
            )

    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_sessions_well_formed(self, seed):
        duration = 300.0
        ct = tenant_churn_trace(
            [2.0, 1.0], duration, mean_session=50.0, mean_absence=30.0,
            seed=seed,
        )
        for sessions in ct.active:
            for (a, b), nxt in zip(sessions, list(sessions[1:]) + [None]):
                assert 0.0 <= a <= b <= duration
                if nxt is not None:
                    assert b < nxt[0]  # an absence separates sessions


class TestJitter:
    @given(sigma=st.floats(0.0, 1.5), seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_jitter_preserves_arrivals_and_order(self, sigma, seed):
        base = poisson_trace([2.0, 1.0], 50.0, seed=seed)
        jit = with_service_jitter(base, sigma=sigma, seed=seed + 1)
        assert len(jit) == len(base)
        for b, j in zip(base, jit):
            assert j.model_idx == b.model_idx
            assert j.arrival == b.arrival
            assert j.service_scale > 0.0

    def test_jitter_is_mean_one(self):
        base = poisson_trace([10.0], 2000.0, seed=4)
        jit = with_service_jitter(base, sigma=0.8, seed=5)
        mean = sum(r.service_scale for r in jit) / len(jit)
        assert mean == pytest.approx(1.0, rel=0.05)

    def test_sigma_zero_is_identity(self):
        base = poisson_trace([2.0], 50.0, seed=6)
        assert with_service_jitter(base, sigma=0.0, seed=7) == base


class TestJsonReplay:
    @given(seed=st.integers(0, 100), sigma=st.floats(0.0, 1.0))
    @settings(max_examples=10, deadline=None)
    def test_round_trip_exact(self, seed, sigma):
        base = with_service_jitter(
            poisson_trace([3.0, 1.0], 60.0, seed=seed), sigma=sigma,
            seed=seed + 1,
        )
        assert trace_from_json(trace_to_json(base)) == base

    def test_round_trip_preserves_service_scale_bits(self):
        reqs = [Request(0, 0.1, service_scale=1.0 / 3.0), Request(1, 0.2)]
        out = trace_from_json(trace_to_json(reqs))
        assert out[0].service_scale == 1.0 / 3.0
        assert out[1].service_scale == 1.0

    def test_from_json_rejects_negative_fields(self):
        with pytest.raises(ValueError):
            trace_from_json('[{"model_idx": 0, "arrival": -1.0}]')
        with pytest.raises(ValueError):
            trace_from_json(
                '[{"model_idx": 0, "arrival": 1.0, "service_scale": -2.0}]'
            )

    def test_from_json_resorts(self):
        out = trace_from_json(
            '[{"model_idx": 0, "arrival": 5.0}, {"model_idx": 1, "arrival": 1.0}]'
        )
        assert [r.arrival for r in out] == [1.0, 5.0]

    def test_replay_drives_simulator_identically(self):
        # A replayed trace is bit-identical, so any simulator run over it
        # reproduces the original run exactly.
        from repro.configs.paper_models import paper_profile
        from repro.core.planner import Plan, TenantSpec
        from repro.hw.specs import EDGE_TPU_PLATFORM as HW
        from repro.serving.simulator import simulate

        ts = [TenantSpec(paper_profile("inceptionv4"), 2.0)]
        plan = Plan((9,), (4,))
        trace = with_service_jitter(
            poisson_trace([2.0], 200.0, seed=8), sigma=0.5, seed=9
        )
        replay = trace_from_json(trace_to_json(trace))
        a = simulate(ts, plan, HW, trace, backend="des")
        b = simulate(ts, plan, HW, replay, backend="des")
        assert a.latencies == b.latencies


class TestTraceProtocol:
    """Edge cases of the ``Trace`` sequence protocol -- the replay contract
    every simulator driver leans on (``__getitem__``/``__iter__``/``__eq__``
    must behave exactly like the ``list[Request]`` they replaced)."""

    def _trace(self, seed=0):
        return with_service_jitter(
            poisson_trace([3.0, 1.0], 40.0, seed=seed), sigma=0.4, seed=seed + 1
        )

    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_getitem_matches_list_semantics(self, seed):
        tr = self._trace(seed)
        as_list = tr.to_requests()
        n = len(tr)
        assert n == len(as_list)
        for i in (0, 1, n - 1, -1, -2, -n):
            assert tr[i] == as_list[i]
        with pytest.raises(IndexError):
            tr[n]
        with pytest.raises(IndexError):
            tr[-n - 1]

    @given(
        seed=st.integers(0, 50),
        start=st.integers(-5, 5),
        stop=st.integers(-5, 5),
        step=st.integers(-3, 3),
    )
    @settings(max_examples=15, deadline=None)
    def test_slices_match_list_semantics(self, seed, start, stop, step):
        if step == 0:
            step = None
        tr = self._trace(seed)
        as_list = tr.to_requests()
        sl = slice(start, stop, step)
        assert tr[sl].to_requests() == as_list[sl]

    def test_empty_and_step_slices(self):
        tr = self._trace()
        assert len(tr[5:5]) == 0
        assert tr[5:5] == []
        assert tr[:] == tr
        half = tr[::2]
        assert half.is_sorted  # positive-step slice of a sorted trace
        rev = tr[::-1]
        assert rev.to_requests() == tr.to_requests()[::-1]
        # A reversed nonempty trace with distinct stamps is not sorted; the
        # flag must be recomputed, not inherited.
        if len(tr) > 1 and tr.arrival[0] != tr.arrival[-1]:
            assert not rev.is_sorted
            assert rev.sorted_by_arrival() == tr

    def test_zero_length_trace(self):
        import numpy as np

        empty = poisson_trace([0.0], 10.0)
        assert len(empty) == 0
        assert list(empty) == []
        assert empty == []
        assert empty.is_sorted
        assert empty.scale_is_unit
        assert empty.sorted_by_arrival() is empty
        assert len(empty[0:0]) == 0
        assert trace_from_json(trace_to_json(empty)) == empty
        sliced = self._trace()[3:3]
        assert np.array_equal(sliced.arrival, np.empty(0))

    def test_eq_against_request_sequences_and_mismatches(self):
        tr = self._trace()
        reqs = tr.to_requests()
        assert tr == reqs
        assert tr == tuple(reqs)
        assert tr != reqs[:-1]
        assert tr != [*reqs[:-1], Request(0, reqs[-1].arrival + 1.0)]
        assert (tr == "not a trace") is False
        assert tr != object()
        jit = with_service_jitter(tr, sigma=0.3, seed=99)
        assert tr != jit  # same arrivals, different service scales


class TestGeneratorJsonRoundTrip:
    """Every generator's output must survive ``trace_to_json`` /
    ``trace_from_json`` bit-identically -- the replay contract had coverage
    only for Poisson(+jitter) traces before; MMPP/diurnal/churn replay
    drives re-runs of every model_vs_sim scenario row."""

    @given(seed=st.integers(0, 30))
    @settings(max_examples=8, deadline=None)
    def test_every_generator_round_trips_bitwise(self, seed):
        import numpy as np

        duration = 60.0
        rates = [2.0, 1.0]
        traces = {
            "poisson": poisson_trace(rates, duration, seed=seed),
            "deterministic": deterministic_trace(rates, duration),
            "dynamic": dynamic_trace(
                [
                    RatePhase(0.0, 30.0, (2.0, 0.5)),
                    RatePhase(30.0, 60.0, (0.5, 2.0)),
                ],
                seed=seed,
            ),
            "mmpp": mmpp_trace(
                rates, duration, burst_factor=3.0, mean_normal=20.0,
                mean_burst=8.0, seed=seed,
            ),
            "diurnal": diurnal_trace(
                rates, duration, amplitude=0.7, period=30.0, seed=seed
            ),
            "churn": tenant_churn_trace(
                rates, duration, mean_session=25.0, mean_absence=15.0,
                seed=seed,
            ).requests,
            "jitter": with_service_jitter(
                mmpp_trace(rates, duration, seed=seed), sigma=0.9,
                seed=seed + 1,
            ),
        }
        for name, tr in traces.items():
            back = trace_from_json(trace_to_json(tr))
            assert np.array_equal(back.model_idx, tr.model_idx), name
            assert np.array_equal(back.arrival, tr.arrival), name
            assert np.array_equal(back.service_scale, tr.service_scale), name
            assert back == tr, name


class TestDynamicPhases:
    def test_dynamic_phases(self):
        phases = [
            RatePhase(0.0, 100.0, (1.0, 0.0)),
            RatePhase(100.0, 200.0, (0.0, 5.0)),
        ]
        reqs = dynamic_trace(phases, seed=3)
        for r in reqs:
            if r.model_idx == 0:
                assert r.arrival < 100.0
            else:
                assert r.arrival >= 100.0
