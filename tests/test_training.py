"""Tests for the training substrate: optimizer, schedules, microbatching,
checkpointing, data pipeline, and loss-goes-down end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens, batches_for_arch
from repro.models.transformer import init_params
from repro.training.checkpoint import restore, save
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.schedule import cosine_schedule, wsd_schedule
from repro.training.train_loop import TrainConfig, make_train_step


class TestAdamW:
    def test_minimizes_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2.0 * params["w"]}
            params, state = adamw_update(grads, state, params, cfg)
        assert np.abs(np.asarray(params["w"])).max() < 0.1

    def test_weight_decay_only_on_matrices(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0)
        params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
        state = adamw_init(params, cfg)
        grads = jax.tree.map(jnp.zeros_like, params)
        new, _ = adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(new["mat"]).sum()) < float(jnp.abs(params["mat"]).sum())
        np.testing.assert_allclose(np.asarray(new["vec"]), 1.0)

    def test_bf16_moments(self):
        cfg = AdamWConfig(moments_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = adamw_init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        _, state = adamw_update({"w": jnp.ones((4,))}, state, params, cfg)
        assert state["v"]["w"].dtype == jnp.bfloat16


class TestSchedules:
    @given(step=st.integers(0, 999))
    @settings(max_examples=30, deadline=None)
    def test_wsd_bounds(self, step):
        s = float(wsd_schedule(step, total_steps=1000))
        assert 0.0 <= s <= 1.0 + 1e-6

    def test_wsd_phases(self):
        total = 1000
        assert float(wsd_schedule(5, total_steps=total)) < 1.0       # warmup
        assert float(wsd_schedule(500, total_steps=total)) == 1.0    # stable
        assert float(wsd_schedule(999, total_steps=total)) < 0.2     # decay

    def test_cosine_monotone_after_warmup(self):
        total = 100
        vals = [float(cosine_schedule(s, total_steps=total)) for s in range(5, 100)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


class TestTrainLoop:
    def test_loss_decreases_qwen_reduced(self):
        cfg = get_arch("qwen1.5-0.5b").reduced()
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3))
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        opt = adamw_init(params, tcfg.optimizer)
        step = jax.jit(make_train_step(cfg, tcfg))
        losses = []
        for i, batch in zip(range(25), batches_for_arch(cfg, 8, 64)):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_microbatching_matches_full_batch(self):
        cfg = get_arch("qwen1.5-0.5b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
        batch = next(iter(batches_for_arch(cfg, 8, 32)))
        batch = jax.tree.map(jnp.asarray, batch)

        outs = {}
        for n_micro in (1, 4):
            tcfg = TrainConfig(
                optimizer=AdamWConfig(lr=1e-3), n_microbatches=n_micro
            )
            opt = adamw_init(params, tcfg.optimizer)
            step = make_train_step(cfg, tcfg)
            new_params, _, m = step(params, opt, batch)
            outs[n_micro] = (new_params, float(m["loss"]))
        # Same data => same loss and (numerically) same update.
        assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
        for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-3, atol=2e-4,
            )

    def test_moe_trains(self):
        cfg = get_arch("grok-1-314b").reduced()
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3))
        params = init_params(cfg, jax.random.PRNGKey(2), dtype=jnp.float32)
        opt = adamw_init(params, tcfg.optimizer)
        step = jax.jit(make_train_step(cfg, tcfg))
        losses = []
        for i, batch in zip(range(15), batches_for_arch(cfg, 4, 32)):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        assert losses[-1] < losses[0]


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_arch("gemma3-1b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(3), dtype=jnp.float32)
        path = str(tmp_path / "ckpt")
        save(path, params, {"arch": cfg.name})
        restored = restore(path, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_metadata(self, tmp_path):
        from repro.training.checkpoint import load_metadata

        path = str(tmp_path / "ckpt")
        save(path, {"x": jnp.ones(3)}, {"k": "v"})
        assert load_metadata(path) == {"k": "v"}


class TestDataPipeline:
    def test_shapes_and_determinism(self):
        dcfg = DataConfig(batch_size=4, seq_len=16, vocab_size=100, seed=7)
        b1 = next(iter(SyntheticTokens(dcfg)))
        b2 = next(iter(SyntheticTokens(dcfg)))
        assert b1["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].max() < 100
        assert b1["tokens"].min() >= 0

    def test_labels_are_shifted_stream(self):
        dcfg = DataConfig(batch_size=2, seq_len=8, vocab_size=50, seed=0)
        b = next(iter(SyntheticTokens(dcfg)))
        # labels[t] == tokens[t+1] by construction
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_vlm_batches(self):
        cfg = get_arch("phi-3-vision-4.2b").reduced()
        b = next(iter(batches_for_arch(cfg, 2, 32)))
        assert "patch_embeds" in b
        assert b["patch_embeds"].shape == (2, cfg.n_patches, cfg.frontend_dim)
