"""Fig. 1 reproduction: intra-model swapping overhead on full-TPU execution.

Paper claim: swapping overhead ranges from 20.2% (DenseNet201) to 62.4%
(InceptionV4) of total processing time for models exceeding the 8 MB SRAM.
"""
from __future__ import annotations

from benchmarks.common import HW, Row
from repro.configs.paper_models import all_paper_profiles
from repro.core.planner import intra_swap_bytes


def run() -> list[Row]:
    rows = []
    for name, prof in all_paper_profiles().items():
        P = prof.num_partition_points
        compute = prof.prefix_tpu_time(P)
        swap = intra_swap_bytes(prof, P, HW) / HW.swap_bw
        total = compute + swap
        frac = 100.0 * swap / total if total else 0.0
        rows.append(
            Row(
                name=f"fig1/{name}",
                us_per_call=total * 1e6,
                derived=f"intra_swap_pct={frac:.1f}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
