"""Predictive re-planning vs reactive, and plan-cache hit economics.

Quantifies what the PR-8 predictive subsystem buys over the reactive
adaptive controller (PAPER.md Section V / Eq. 10):

* **Reactive-vs-predictive gap** -- the drift-mix trio (densenet201 /
  mobilenetv2 / squeezenet, the mix whose plan is most rate-sensitive) on
  two forecastable drift scenarios:

  - ``mmpp``: an MMPP(2) bursty trace (same phase construction as
    ``workload.mmpp_trace``, built here from explicit ``RatePhase``s so the
    *oracle* forecaster can be handed the true piecewise rate function).
    Reactive re-planning pays a stale-plan window at every state
    transition -- the burst plan lands one sliding window after the burst;
    with utilization near the stability edge that window is where queueing
    blows up, so the oracle gap is large.
  - ``diurnal``: a sinusoidal Lewis-Shedler thinned trace.  The
    ``PeriodicForecaster`` learns the binned profile during the first
    cycle and anticipates every later one; the oracle knows the closed
    form.

  Each scenario reports reactive / learned-forecaster / oracle mean and
  pooled p99 latency and the mean gain percentages.  The oracle rows bound
  what any forecaster can buy; the learned rows are what the shipped
  ``EwmaTrendForecaster`` / ``PeriodicForecaster`` actually deliver (the
  EWMA trend can *lose* on square-wave MMPP transitions -- it extrapolates
  through the state flip -- which the numbers report honestly).
  The acceptance bar is a >= 10% mean-latency gain on at least one
  MMPP or diurnal mix.

* **Plan-cache economics** -- (a) a controller-level run on a repeating
  diurnal trace with the ``PeriodicForecaster`` feeding a ``PlanCache``:
  once the learned profile converges, forecast rate vectors for recurring
  daily states quantize onto the same keys and re-plans become cache hits
  (reactive estimates almost never repeat a 64-dim cell -- forecast-driven
  keys are what make memoization effective, and the run records both hit
  rates); (b) a 64-tenant microbenchmark: cold ``hill_climb``, warm
  ``hill_climb``, and a memoized warm *hit* (lookup + verify evaluation)
  for a recurring rate state.  The acceptance bar is a verified hit in
  < 1 ms at 64 tenants (the PR-2 warm budget is 5 ms).

Before anything is timed, the opt-in contract is self-checked **bitwise**:
``run_adaptive`` with no forecaster/cache, with explicit
``forecaster=None, plan_cache=None``, and with a never-warm forecaster
(``NeverForecaster``) must commit identical plans and produce identical
latencies -- the no-forecaster path IS the reactive controller (standing
ROADMAP invariant).

Usage:
    PYTHONPATH=src python -m benchmarks.predictive [--smoke]
        [--seed N] [--out BENCH_predictive.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import HW, K_MAX, Row
from repro.configs.paper_models import all_paper_profiles, paper_profile
from repro.core.allocator import hill_climb
from repro.core.plan_cache import PlanCache
from repro.core.planner import TenantSpec
from repro.serving.controller import run_adaptive
from repro.serving.forecast import (
    EwmaTrendForecaster,
    NeverForecaster,
    OracleForecaster,
    PeriodicForecaster,
    piecewise_rate_fn,
)
from repro.serving.workload import RatePhase, diurnal_trace, dynamic_trace

# The controller/estimator drift mix (tests/test_controller_engine.py): the
# plan for this trio swings hard with the rate vector, so stale plans cost
# real latency -- exactly where forecasting pays.
MODELS = ("densenet201", "mobilenetv2", "squeezenet")
BASE_RATES = (2.2, 1.0, 3.2)

REPLAN = 30.0
WINDOW = 30.0


def _profiles():
    return [paper_profile(m) for m in MODELS]


def _pooled_p99(sim) -> float:
    """Nearest-rank p99 over all models' completions pooled (the fleet-wide
    tail, same integer-rank rule as ``SimResult.p99``)."""
    alls = np.concatenate(
        [np.asarray(ls) for ls in sim.latencies if len(ls)]
    )
    n = alls.size
    if n == 0:
        return float("nan")
    k = (99 * n + 99) // 100
    return float(np.partition(alls, k - 1)[k - 1])


def _mmpp_phases(
    rates, duration: float, *, burst_factor, mean_normal, mean_burst, seed
) -> list[RatePhase]:
    """The exact phase construction of ``workload.mmpp_trace``, exposed so
    the oracle forecaster can see the true piecewise rate function."""
    rng = np.random.default_rng(seed)
    phases, t, burst = [], 0.0, False
    while t < duration:
        mean = mean_burst if burst else mean_normal
        hold = float(rng.exponential(mean))
        end = min(t + hold, duration)
        mult = burst_factor if burst else 1.0
        phases.append(RatePhase(t, end, tuple(r * mult for r in rates)))
        t, burst = end, not burst
    return phases


def _diurnal_fn(rates, amplitude: float, period: float):
    import math

    def fn(t: float):
        s = 1.0 + amplitude * math.sin(2.0 * math.pi * t / period)
        return tuple(r * s for r in rates)

    return fn


def self_check_reactive_pin(seed: int) -> None:
    """Opt-in contract, bitwise: no-forecaster/no-cache == reactive."""
    profs = _profiles()
    phases = [
        RatePhase(0.0, 120.0, BASE_RATES),
        RatePhase(120.0, 240.0, (11.4, 1.3, 2.9)),
    ]
    trace = dynamic_trace(phases, seed=seed)
    common = dict(
        replan_period=REPLAN, window=WINDOW, initial_rates=BASE_RATES
    )
    ref = run_adaptive(profs, trace, HW, K_MAX, **common)
    explicit = run_adaptive(
        profs, trace, HW, K_MAX, forecaster=None, plan_cache=None, **common
    )
    never = run_adaptive(
        profs, trace, HW, K_MAX, forecaster=NeverForecaster(), **common
    )
    for name, got in (("explicit-None", explicit), ("NeverForecaster", never)):
        if got.plans != ref.plans or got.replan_times != ref.replan_times:
            raise AssertionError(
                f"opt-in pin broken: {name} committed different plans"
            )
        for i in range(len(profs)):
            if not np.array_equal(
                np.asarray(ref.sim.latencies[i]),
                np.asarray(got.sim.latencies[i]),
            ):
                raise AssertionError(
                    f"opt-in pin broken: {name} latencies drifted (model {i})"
                )


def _gap_row(name, sim, reactive_mean) -> dict:
    mean = sim.overall_mean()
    return {
        "variant": name,
        "mean_s": mean,
        "p99_s": _pooled_p99(sim),
        "mean_gain_pct": 100.0 * (1.0 - mean / reactive_mean),
    }


def mmpp_gap(duration: float, seed: int) -> dict:
    profs = _profiles()
    phases = _mmpp_phases(
        BASE_RATES,
        duration,
        burst_factor=4.0,
        mean_normal=120.0,
        mean_burst=60.0,
        seed=seed,
    )
    # Same seed offset mmpp_trace uses for the arrival draw.
    trace = dynamic_trace(phases, seed=seed + 104729)
    common = dict(
        replan_period=REPLAN, window=WINDOW, initial_rates=BASE_RATES
    )
    reactive = run_adaptive(profs, trace, HW, K_MAX, **common)
    ewma = run_adaptive(
        profs,
        trace,
        HW,
        K_MAX,
        forecaster=EwmaTrendForecaster(len(profs)),
        **common,
    )
    oracle = run_adaptive(
        profs,
        trace,
        HW,
        K_MAX,
        forecaster=OracleForecaster(piecewise_rate_fn(phases)),
        **common,
    )
    r_mean = reactive.sim.overall_mean()
    return {
        "scenario": "mmpp",
        "seed": seed,
        "duration_s": duration,
        "trace_requests": len(trace),
        "variants": [
            _gap_row("reactive", reactive.sim, r_mean),
            _gap_row("ewma_trend", ewma.sim, r_mean),
            _gap_row("oracle", oracle.sim, r_mean),
        ],
    }


def diurnal_gap(duration: float, seed: int) -> dict:
    profs = _profiles()
    amplitude, period = 0.9, 300.0
    rates = tuple(r * 1.4 for r in BASE_RATES)
    trace = diurnal_trace(
        list(rates), duration, amplitude=amplitude, period=period, seed=seed
    )
    common = dict(replan_period=REPLAN, window=WINDOW, initial_rates=rates)
    reactive = run_adaptive(profs, trace, HW, K_MAX, **common)
    periodic = run_adaptive(
        profs,
        trace,
        HW,
        K_MAX,
        forecaster=PeriodicForecaster(
            len(profs), period, n_bins=int(period // REPLAN)
        ),
        **common,
    )
    oracle = run_adaptive(
        profs,
        trace,
        HW,
        K_MAX,
        forecaster=OracleForecaster(_diurnal_fn(rates, amplitude, period)),
        **common,
    )
    r_mean = reactive.sim.overall_mean()
    return {
        "scenario": "diurnal",
        "seed": seed,
        "duration_s": duration,
        "amplitude": amplitude,
        "period_s": period,
        "trace_requests": len(trace),
        "variants": [
            _gap_row("reactive", reactive.sim, r_mean),
            _gap_row("periodic", periodic.sim, r_mean),
            _gap_row("oracle", oracle.sim, r_mean),
        ],
    }


def cache_controller_run(duration: float, seed: int) -> dict:
    """Repeating diurnal trace: forecast-driven keys make recurring daily
    states cache hits; reactive keys almost never repeat.  Reports both."""
    profs = _profiles()
    period = 300.0
    trace = diurnal_trace(
        list(BASE_RATES), duration, amplitude=0.9, period=period, seed=seed
    )
    common = dict(
        replan_period=REPLAN, window=WINDOW, initial_rates=BASE_RATES
    )
    forecast_cache = PlanCache(rel=0.10, margin=0.10)
    run_adaptive(
        profs,
        trace,
        HW,
        K_MAX,
        forecaster=PeriodicForecaster(
            len(profs), period, n_bins=int(period // REPLAN)
        ),
        plan_cache=forecast_cache,
        **common,
    )
    reactive_cache = PlanCache(rel=0.10, margin=0.10)
    run_adaptive(profs, trace, HW, K_MAX, plan_cache=reactive_cache, **common)
    return {
        "duration_s": duration,
        "period_s": period,
        "forecast_keys": forecast_cache.stats.as_dict(),
        "reactive_keys": reactive_cache.stats.as_dict(),
    }


def cache_microbench(n_tenants: int = 64, seed: int = 0) -> dict:
    """Cold climb vs warm climb vs memoized warm hit for a recurring state."""
    names = list(all_paper_profiles())
    profs = [paper_profile(names[i % len(names)]) for i in range(n_tenants)]
    rng = np.random.default_rng(seed)
    rates = (0.05 + rng.uniform(size=n_tenants) * 0.4).tolist()
    tenants = [TenantSpec(p, r) for p, r in zip(profs, rates)]
    k_max = max(HW.cpu.n_cores, n_tenants)

    t0 = time.perf_counter()
    plan, obj = hill_climb(tenants, HW, k_max)
    cold_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    hill_climb(tenants, HW, k_max, init_plan=plan)
    warm_ms = (time.perf_counter() - t0) * 1e3

    cache = PlanCache()
    cache.store(tenants, HW, k_max, plan, obj)
    # The recurring state: the same rate cell comes back (e.g. tomorrow's
    # instance of today's traffic).  Best-of-7 to shave timer noise.
    hit_ms = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        hit = cache.lookup(tenants, HW, k_max)
        hit_ms = min(hit_ms, (time.perf_counter() - t0) * 1e3)
        if hit is None:
            raise AssertionError("recurring-state lookup must hit")
        if hit[0] != plan:
            raise AssertionError("cache hit returned a different plan")
    return {
        "n_tenants": n_tenants,
        "cold_ms": cold_ms,
        "warm_ms": warm_ms,
        "cache_hit_ms": hit_ms,
        "stats": cache.stats.as_dict(),
    }


def run_sweep(*, smoke: bool = False, seed: int = 3) -> dict:
    self_check_reactive_pin(seed + 2)

    if smoke:
        scenarios = [mmpp_gap(200.0, seed), diurnal_gap(600.0, seed + 4)]
        cache_run = cache_controller_run(600.0, seed + 4)
    else:
        scenarios = [
            mmpp_gap(600.0, seed),
            mmpp_gap(600.0, seed + 6),
            diurnal_gap(1500.0, seed + 4),
        ]
        cache_run = cache_controller_run(1500.0, seed + 4)
    micro = cache_microbench()

    best_gain, best_label = float("-inf"), ""
    for sc in scenarios:
        for v in sc["variants"]:
            if v["variant"] == "reactive":
                continue
            if v["mean_gain_pct"] > best_gain:
                best_gain = v["mean_gain_pct"]
                best_label = f"{sc['scenario']}(seed={sc['seed']})/{v['variant']}"
    return {
        "benchmark": "predictive",
        "self_check": "reactive_pin_bitwise_ok",
        "scenarios": scenarios,
        "cache_controller": cache_run,
        "cache_micro": micro,
        "headline": {
            "predictive_mean_gain_pct": best_gain,
            "predictive_best_variant": best_label,
            "gain_target_pct": 10.0,
            "cache_hit_ms_64t": micro["cache_hit_ms"],
            "cache_hit_target_ms": 1.0,
            "forecast_key_hit_rate": cache_run["forecast_keys"]["hit_rate"],
        },
    }


def _rows_of(report: dict) -> list[Row]:
    rows = []
    for sc in scenarios_of(report):
        reactive = next(
            v for v in sc["variants"] if v["variant"] == "reactive"
        )
        for v in sc["variants"]:
            rows.append(
                Row(
                    f"predictive/{sc['scenario']}_s{sc['seed']}/{v['variant']}",
                    v["mean_s"] * 1e6,
                    f"gain_pct={v['mean_gain_pct']:.1f};"
                    f"p99_ms={v['p99_s']*1e3:.1f};"
                    f"reactive_mean_ms={reactive['mean_s']*1e3:.2f}",
                )
            )
    micro = report["cache_micro"]
    rows.append(
        Row(
            f"predictive/cache_hit/{micro['n_tenants']}ten",
            micro["cache_hit_ms"] * 1e3,
            f"cold_ms={micro['cold_ms']:.1f};warm_ms={micro['warm_ms']:.1f};"
            f"hit_ms={micro['cache_hit_ms']:.3f}",
        )
    )
    cc = report["cache_controller"]
    rows.append(
        Row(
            "predictive/cache_hit_rate/forecast_keys",
            cc["forecast_keys"]["hit_rate"] * 1e2,
            f"hits={cc['forecast_keys']['hits']};"
            f"misses={cc['forecast_keys']['misses']};"
            f"reactive_hit_rate={cc['reactive_keys']['hit_rate']:.2f}",
        )
    )
    return rows


def scenarios_of(report: dict) -> list[dict]:
    return report["scenarios"]


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(run_sweep(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short traces: CI sanity (self-check + shape), not a record",
    )
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--out", default="BENCH_predictive.json")
    args = ap.parse_args()
    report = run_sweep(smoke=args.smoke, seed=args.seed)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    h = report["headline"]
    print(
        f"# headline: predictive re-planning cuts mean latency "
        f"{h['predictive_mean_gain_pct']:.1f}% vs reactive on "
        f"{h['predictive_best_variant']} "
        f"(target >= {h['gain_target_pct']:.0f}%); 64-tenant memoized "
        f"warm hit {h['cache_hit_ms_64t']:.3f} ms "
        f"(target < {h['cache_hit_target_ms']:.0f} ms); forecast-key "
        f"hit rate {h['forecast_key_hit_rate']:.0%}"
    )
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
