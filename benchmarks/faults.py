"""Fault injection & self-healing: fault-aware vs fault-oblivious serving.

Measures what PR 9's fault-aware control loop buys when the fleet actually
misbehaves.  Three scheduled-fault scenarios run the same trace through the
adaptive fleet controller twice -- ``fault_aware=False`` (the controller
keeps routing to a dead device and planning against nominal speeds) and
``fault_aware=True`` (observed-signal detection, out-of-band failover /
restore placement re-plans, degraded-spec planning):

* ``dropout`` -- one device goes silent for several re-plan windows
  (requeue policy: its requests defer to recovery).  The aware controller
  detects the stalled completions, evacuates the device
  (``core.fleet.evacuate_device``), and re-admits it on recovery.  The
  acceptance bar is a >= 20% request-weighted mean-latency win.
* ``throttle`` -- one device runs at a fraction of nominal speed (thermal
  throttling).  The aware controller estimates the slowdown from observed
  vs predicted windowed means and re-plans against the degraded
  ``DeviceSpec``; the throttle *transition* triggers a cold placement
  search, migrating load off the slow device.
* ``swap_degrade`` -- host<->accelerator transfer bandwidth collapses
  (swap-heavy mixes pay it on every miss and transfer).

Every scenario reports both controllers' request-weighted mean latency,
recovery metrics (time-to-recover per outage window, requests
lost/requeued, mean latency inside fault windows) and the fault-aware
event log (failover / restore / degraded re-plan times).

Before anything is timed, the standing no-fault invariant is self-checked
**bitwise** (and the run aborts on any drift):

* ``faults=None`` DES == the frozen pre-fault reference
  (``benchmarks.des_baseline.BaselineDiscreteEventSimulator``), elementwise;
* stepper/DES with ``faults=None`` and with an *empty* ``FaultSchedule``
  == the plain no-kwarg construction, elementwise;
* ``run_adaptive`` and ``run_adaptive_fleet`` with explicit
  ``faults=None, fault_aware=False`` == their defaults (plans and
  latencies identical).

Usage:
    PYTHONPATH=src python -m benchmarks.faults [--smoke]
        [--seed N] [--out BENCH_faults.json]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import HW, K_MAX, Row
from benchmarks.des_baseline import BaselineDiscreteEventSimulator
from repro.configs.paper_models import paper_profile
from repro.core.allocator import hill_climb
from repro.core.fleet import DeviceSpec
from repro.core.planner import TenantSpec
from repro.serving.controller import run_adaptive
from repro.serving.des import DiscreteEventSimulator
from repro.serving.faults import FaultEvent, FaultSchedule
from repro.serving.fleet import run_adaptive_fleet
from repro.serving.simulator import RuntimeSimulator
from repro.serving.workload import poisson_trace

MODELS = ("mnasnet", "inceptionv4", "mobilenetv2", "densenet201")
RATES = (8.0, 5.0, 7.0, 3.0)
# The swap scenario needs a mix that actually swaps: six large models on
# three devices overflow per-device SRAM, so TPU services pay T_load on
# (nearly) every request and a bandwidth collapse is catastrophic.  The
# lighter 4-model mix above ends up fully resident per device -- zero
# misses, nothing for a swap fault to degrade.
SWAP_MODELS = (
    "densenet201", "resnet50v2", "xception", "inceptionv4", "gpunet",
    "efficientnet",
)
SWAP_RATES = (3.0, 3.0, 2.5, 2.5, 3.0, 3.0)
N_DEVICES = 3
REPLAN = 15.0
WINDOW = 30.0


def _profiles():
    return [paper_profile(m) for m in MODELS]


def _fleet():
    return [
        DeviceSpec.from_platform(HW, name=f"dev{i}") for i in range(N_DEVICES)
    ]


def _latencies_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    return all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, b)
    )


def self_check_no_fault_pin(seed: int) -> None:
    """Standing invariant, bitwise: the ``faults=None`` path IS the
    pre-fault code on every backend and both controllers."""
    profiles = _profiles()[:2]
    rates = RATES[:2]
    trace = list(poisson_trace(list(rates), duration=60.0, seed=seed))
    tenants = [TenantSpec(p, r) for p, r in zip(profiles, rates)]
    plan, _ = hill_climb(tenants, HW, K_MAX)
    empty = FaultSchedule(events=())

    # DES vs the frozen pre-fault reference, and both fault spellings.
    ref = BaselineDiscreteEventSimulator(profiles, plan, HW)
    variants = {
        "des": DiscreteEventSimulator(profiles, plan, HW),
        "des_faults_none": DiscreteEventSimulator(
            profiles, plan, HW, faults=None
        ),
        "des_faults_empty": DiscreteEventSimulator(
            profiles, plan, HW, faults=empty.view(0)
        ),
    }
    for req in trace:
        ref.offer(req)
        for sim in variants.values():
            sim.offer(req)
    ref_d = ref.drain()
    for name, sim in variants.items():
        sim.drain()
        if not _latencies_equal(ref.latencies, sim.latencies):
            raise AssertionError(
                f"no-fault pin broken: {name} drifted from the frozen "
                "pre-fault DES"
            )
    del ref_d

    # Stepper: both fault spellings against the plain construction.
    st_ref = RuntimeSimulator(profiles, plan, HW)
    st_none = RuntimeSimulator(profiles, plan, HW, faults=None)
    st_empty = RuntimeSimulator(profiles, plan, HW, faults=empty.view(0))
    for req in trace:
        for sim in (st_ref, st_none, st_empty):
            sim.offer(req)
    for name, sim in (("faults=None", st_none), ("empty schedule", st_empty)):
        sim.drain()
        if not _latencies_equal(st_ref.latencies, sim.latencies):
            raise AssertionError(f"no-fault pin broken: stepper {name}")

    # Controllers: explicit fault kwargs at their defaults == the defaults.
    full = _profiles()
    ftrace = poisson_trace(list(RATES), duration=90.0, seed=seed + 1)
    kw = dict(replan_period=REPLAN, window=WINDOW, backend="des")
    a_ref = run_adaptive(full, ftrace, HW, K_MAX, **kw)
    a_exp = run_adaptive(
        full, ftrace, HW, K_MAX, faults=None, fault_aware=False, **kw
    )
    if a_ref.plans != a_exp.plans or not _latencies_equal(
        a_ref.sim.latencies, a_exp.sim.latencies
    ):
        raise AssertionError("no-fault pin broken: run_adaptive")
    fleet = _fleet()
    f_ref = run_adaptive_fleet(full, ftrace, fleet, **kw)
    f_exp = run_adaptive_fleet(
        full, ftrace, fleet, faults=None, fault_aware=False, **kw
    )
    if f_ref.fleet_plans != f_exp.fleet_plans or not _latencies_equal(
        f_ref.sim.latencies, f_exp.sim.latencies
    ):
        raise AssertionError("no-fault pin broken: run_adaptive_fleet")


def _scenario_faults(kind: str, duration: float) -> FaultSchedule:
    """One mid-trace fault window spanning several re-plan periods."""
    start, end = 0.2 * duration, 0.6 * duration
    if kind == "dropout":
        ev = FaultEvent(kind="dropout", device=1, start=start, end=end)
        return FaultSchedule(events=(ev,), dropout_policy="requeue")
    if kind == "throttle":
        ev = FaultEvent(
            kind="throttle",
            device=0,
            start=start,
            end=end,
            tpu_factor=0.25,
            cpu_factor=0.25,
        )
        return FaultSchedule(events=(ev,))
    if kind == "swap_degrade":
        # Device 1 hosts the miss-heavy share of the SWAP_MODELS placement.
        ev = FaultEvent(
            kind="swap_degrade", device=1, start=start, end=end,
            swap_factor=0.1,
        )
        return FaultSchedule(events=(ev,))
    raise ValueError(kind)


def _controller_metrics(res, rates) -> dict:
    sim = res.sim
    return {
        "request_weighted_mean_s": sim.request_weighted_mean(rates),
        "overall_mean_s": sim.overall_mean(),
        "requests_lost": sim.requests_lost,
        "requests_requeued": sim.requests_requeued,
        "recovery_times_s": sim.recovery_times(),
        "degraded_window_mean_s": sim.degraded_window_mean(),
        "failover_times": list(res.failover_times),
        "restore_times": list(res.restore_times),
        "degraded_replan_times": list(res.degraded_replan_times),
        "placement_replan_times": list(res.placement_replan_times),
    }


def scenario(kind: str, duration: float, seed: int) -> dict:
    if kind == "swap_degrade":
        models, rates = SWAP_MODELS, SWAP_RATES
    else:
        models, rates = MODELS, RATES
    profiles = [paper_profile(m) for m in models]
    trace = poisson_trace(list(rates), duration=duration, seed=seed)
    fleet = _fleet()
    faults = _scenario_faults(kind, duration)
    kw = dict(replan_period=REPLAN, window=WINDOW, backend="des")
    oblivious = run_adaptive_fleet(
        profiles, trace, fleet, faults=faults, fault_aware=False, **kw
    )
    aware = run_adaptive_fleet(
        profiles, trace, fleet, faults=faults, fault_aware=True, **kw
    )
    m_obl = oblivious.sim.request_weighted_mean(rates)
    m_aw = aware.sim.request_weighted_mean(rates)
    return {
        "scenario": kind,
        "seed": seed,
        "duration_s": duration,
        "models": list(models),
        "trace_requests": len(trace),
        "fault_windows": [
            [e.start, e.end] for e in faults.events
        ],
        "oblivious": _controller_metrics(oblivious, rates),
        "aware": _controller_metrics(aware, rates),
        "mean_improvement_pct": 100.0 * (1.0 - m_aw / m_obl),
    }


def run_sweep(*, smoke: bool = False, seed: int = 7) -> dict:
    self_check_no_fault_pin(seed + 1)
    duration = 300.0 if smoke else 600.0
    scenarios = [
        scenario(kind, duration, seed)
        for kind in ("dropout", "throttle", "swap_degrade")
    ]
    dropout = next(s for s in scenarios if s["scenario"] == "dropout")
    return {
        "benchmark": "faults",
        "self_check": "no_fault_pin_bitwise_ok",
        "scenarios": scenarios,
        "headline": {
            "dropout_mean_improvement_pct": dropout["mean_improvement_pct"],
            "improvement_target_pct": 20.0,
            "dropout_ttr_oblivious_s": dropout["oblivious"][
                "recovery_times_s"
            ],
            "dropout_ttr_aware_s": dropout["aware"]["recovery_times_s"],
            "dropout_requeued_oblivious": dropout["oblivious"][
                "requests_requeued"
            ],
            "dropout_requeued_aware": dropout["aware"]["requests_requeued"],
        },
    }


def _rows_of(report: dict) -> list[Row]:
    rows = []
    for sc in report["scenarios"]:
        for variant in ("oblivious", "aware"):
            m = sc[variant]
            rows.append(
                Row(
                    f"faults/{sc['scenario']}/{variant}",
                    m["request_weighted_mean_s"] * 1e6,
                    f"improvement_pct={sc['mean_improvement_pct']:.1f};"
                    f"lost={m['requests_lost']};"
                    f"requeued={m['requests_requeued']};"
                    f"ttr_s={[round(t, 2) for t in m['recovery_times_s']]}",
                )
            )
    return rows


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(run_sweep(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short traces: CI sanity (self-check + shape), not a record",
    )
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    report = run_sweep(smoke=args.smoke, seed=args.seed)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    h = report["headline"]
    print(
        f"# headline: fault-aware control cuts dropout request-weighted "
        f"mean latency {h['dropout_mean_improvement_pct']:.1f}% vs the "
        f"fault-oblivious controller (target >= "
        f"{h['improvement_target_pct']:.0f}%); time-to-recover "
        f"{h['dropout_ttr_aware_s']} s aware vs "
        f"{h['dropout_ttr_oblivious_s']} s oblivious; "
        f"{h['dropout_requeued_aware']} vs "
        f"{h['dropout_requeued_oblivious']} deferrals"
    )
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
