"""Beyond-paper ablation: how conservative is the alpha upper bound (Eq.10)?

The paper approximates the weight-miss probability with
``alpha_i = 1 - lambda_i/lambda_TPU`` ("any intervening request of a
different model evicts M_i") because the Edge TPU's eviction policy is
proprietary.  Our explicit LRU cache simulator measures the *actual* miss
rate, so we can quantify the approximation error across memory-pressure
regimes -- and evaluate how much latency prediction accuracy it costs.

Key expectation: with 2 tenants whose footprints both exceed the leftover
capacity, LRU == the conservative bound (every alternation evicts).  With
*partial* fits (small model + big model where the small one is never
evicted) the bound overestimates.
"""
from __future__ import annotations

from benchmarks.common import HW, Row, tenants
from repro.configs.paper_models import paper_profile
from repro.core import latency, swap
from repro.core.allocator import edge_tpu_compiler_plan
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

DURATION = 3000.0

# (name, models, rates) -- spanning no-pressure to heavy-pressure regimes.
SCENARIOS = [
    ("fits", ["mobilenetv2", "squeezenet"], (2.0, 2.0)),
    ("tight_5050", ["efficientnet", "gpunet"], (2.0, 2.0)),
    ("tight_9010", ["efficientnet", "gpunet"], (3.6, 0.4)),
    # Partial fit: squeezenet (1.4MB) + inceptionv4 (43.2MB > C alone):
    # LRU keeps squeezenet resident most of the time -> bound conservative.
    ("partial_fit", ["squeezenet", "inceptionv4"], (3.0, 1.0)),
    ("three_way", ["efficientnet", "gpunet", "densenet201"], (1.5, 1.5, 1.0)),
]


def run() -> list[Row]:
    rows = []
    for name, names, rates in SCENARIOS:
        profs = [paper_profile(n) for n in names]
        ts = tenants(profs, list(rates))
        plan = edge_tpu_compiler_plan(ts)
        alphas = swap.weight_miss_probs(ts, plan.partition, HW)
        reqs = poisson_trace(list(rates), DURATION, seed=21)
        sim = simulate(ts, plan, HW, reqs)
        pred = latency.predict(ts, plan, HW)
        for i, n in enumerate(names):
            obs = sim.observed_miss_rate(i)
            a = alphas[i]
            gap = a - obs
            rows.append(
                Row(
                    name=f"alpha_ablation/{name}/{n}",
                    us_per_call=sim.mean_latency(i) * 1e6,
                    derived=(
                        f"alpha={a:.2f};observed={obs:.2f};"
                        f"conservatism={gap:+.2f};"
                        f"pred_err_pct={100*abs(pred.latencies[i]-sim.mean_latency(i))/max(sim.mean_latency(i),1e-12):.1f}"
                    ),
                )
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
