"""SLO objectives vs the Eq. 5 mean on a tail-sensitive mix.

The paper's planner minimizes mean latency (Eq. 5); the PR-10 objective
layer makes the metric pluggable (``repro.core.objective``).  This
benchmark demonstrates the payoff on the mix where mean-optimal is
tail-wrong: one bursty heavy tenant (inceptionv4 under an MMPP(2)
arrival process, 5x bursts) sharing the Edge TPU with two
latency-critical light tenants (squeezenet / mobilenetv2, Poisson, with
per-tenant deadline budgets).

* The **mean** plan splits a light tenant across TPU + CPU: lowest
  average latency, but the split tenant waits in the heavy tenant's TPU
  queue, which explodes during bursts -- the pooled p99 eats it.
* The **p_tail(0.99)** plan pays ~25% more mean to move that tenant
  fully onto the CPU pool, out of the burst blast radius.  Acceptance
  bar: >= 15% pooled-p99 reduction vs the mean plan on the DES ground
  truth, with the deadline-miss rate also improving and the mean given
  up reported honestly.
* The **deadline_miss** plan is climbed twice: cold (Algorithm 1's
  all-CPU start) and warm-started from the mean plan.  The cold climb
  exposes an honest limitation -- the miss-probability surface
  plateaus (miss saturates at 0 or 1), the greedy climb gets stuck
  sacrificing the low-rate heavy tenant to the CPU pool, and the
  analytic model (Poisson arrivals) calls that plan stable when 5x
  bursts make it catastrophic in the DES.  Both plans' objective values
  and DES outcomes are reported so the gap is visible.

Before anything is timed, the opt-in contract is self-checked
**bitwise**: ``objective=None`` must reproduce the pre-refactor mean
path exactly on every layer -- scalar ``penalized_objective``, the
batched and delta ``EvalTables`` paths, ``JaxPlanEvaluator``,
``hill_climb``, ``fleet_hill_climb`` / ``fleet_plan_objective``,
``PlanCache`` keys, and ``run_adaptive`` (including
``rate_margin=None`` / ``deadlines=None``) -- the "objectives are
opt-in; mean stays pinned" ROADMAP standing invariant.

Usage:
    PYTHONPATH=src python -m benchmarks.slo [--smoke]
        [--seed N] [--out BENCH_slo.json]
"""
from __future__ import annotations

import argparse
import json
import math

import numpy as np

from benchmarks.common import HW, K_MAX, Row
from repro.core import latency
from repro.core.allocator import hill_climb
from repro.core.fleet import DeviceSpec, fleet_hill_climb, fleet_plan_objective
from repro.core.jax_eval import JaxPlanEvaluator
from repro.core.objective import MEAN, deadline_miss, p_tail
from repro.core.plan_cache import PlanCache
from repro.core.planner import TenantSpec
from repro.core.plan_tables import EvalTables
from repro.configs.paper_models import paper_profile
from repro.serving.controller import run_adaptive
from repro.serving.simulator import simulate
from repro.serving.workload import Trace, mmpp_trace, poisson_trace

# Heavy bursty tenant first, then the two latency-critical lights.  The
# rates put the system near rho ~ 0.35 at the normal phase; the 5x burst
# phases are where the plans separate.
MODELS = ("inceptionv4", "squeezenet", "mobilenetv2")
RATES = (0.3, 5.0, 3.75)
DEADLINES = (0.25, 0.10, 0.12)
BURST_FACTOR = 5.0
MEAN_NORMAL_S = 60.0
MEAN_BURST_S = 20.0
P99_GAIN_TARGET_PCT = 15.0


def _tenants(deadlines=DEADLINES):
    profs = [paper_profile(m) for m in MODELS]
    return [
        TenantSpec(p, r, deadline=d)
        for p, r, d in zip(profs, RATES, deadlines)
    ]


def _trace(duration: float, seed: int) -> Trace:
    """Only the heavy tenant is bursty; the lights stay Poisson."""
    heavy = mmpp_trace(
        [RATES[0], 0.0, 0.0],
        duration,
        burst_factor=BURST_FACTOR,
        mean_normal=MEAN_NORMAL_S,
        mean_burst=MEAN_BURST_S,
        seed=seed,
    )
    lights = poisson_trace([0.0, RATES[1], RATES[2]], duration, seed=seed + 1)
    idx = np.concatenate([heavy.model_idx, lights.model_idx])
    arr = np.concatenate([heavy.arrival, lights.arrival])
    order = np.argsort(arr, kind="stable")
    return Trace(idx[order], arr[order])


def _pooled_p99(sim) -> float:
    """Nearest-rank p99 over all completions pooled (``SimResult.p99``'s
    integer-rank rule applied fleet-wide)."""
    alls = np.concatenate(
        [np.asarray(ls, dtype=np.float64) for ls in sim.latencies if len(ls)]
    )
    n = alls.size
    if n == 0:
        return float("nan")
    k = (99 * n + 99) // 100
    return float(np.partition(alls, k - 1)[k - 1])


# --------------------------------------------------------------------------
# Self-check: objective=None is bitwise the pre-refactor mean on every layer.
# --------------------------------------------------------------------------


def _check(cond: bool, what: str) -> None:
    if not cond:
        raise AssertionError(f"objective=None pin broken: {what}")


def self_check_mean_pin(seed: int) -> None:
    ts = _tenants()
    plan, obj = hill_climb(ts, HW, K_MAX)

    # Scalar reference path.
    ref = latency.penalized_objective(ts, plan, HW)
    for tag, o in (("None", None), ("MEAN", MEAN)):
        got = latency.penalized_objective(ts, plan, HW, objective=o)
        _check(got == ref, f"scalar penalized_objective (objective={tag})")

    # Batched + delta EvalTables paths over the hill-climb's own frontier.
    rng = np.random.default_rng(seed)
    n = len(ts)
    npts = [t.profile.num_partition_points for t in ts]
    P = np.stack(
        [rng.integers(0, np.asarray(npts) + 1) for _ in range(16)]
    ).astype(np.intp)
    K = rng.integers(0, K_MAX + 1, size=(16, n)).astype(np.intp)
    et = EvalTables.build(ts, HW, K_MAX)
    ref_b = latency.penalized_objective_batch(ts, P, K, HW, tables=et)
    got_b = latency.penalized_objective_batch(
        ts, P, K, HW, tables=et, objective=None
    )
    _check(np.array_equal(ref_b, got_b), "batched penalized_objective_batch")
    base_p = np.asarray(plan.partition, dtype=np.intp)
    base_k = np.asarray(plan.cores, dtype=np.intp)
    ref_d = latency.penalized_objective_delta_batch(
        ts, base_p, base_k, P, K, HW, tables=et
    )
    got_d = latency.penalized_objective_delta_batch(
        ts, base_p, base_k, P, K, HW, tables=et, objective=None
    )
    _check(np.array_equal(ref_d, got_d), "delta penalized_objective_delta_batch")

    # JAX evaluator path.
    ev = JaxPlanEvaluator.build(ts, HW, K_MAX, tables=et)
    ref_j = ev.penalized_objective_batch(P, K)
    got_j = ev.penalized_objective_batch(P, K, objective=None)
    _check(np.array_equal(ref_j, got_j), "JaxPlanEvaluator batch")

    # Planner path.
    plan2, obj2 = hill_climb(ts, HW, K_MAX, objective=None)
    _check(
        plan2.partition == plan.partition
        and plan2.cores == plan.cores
        and obj2 == obj,
        "hill_climb(objective=None)",
    )

    # Fleet path (N=1 degenerate fleet).
    fleet = [DeviceSpec.from_platform(HW, name="d0")]
    fp_ref, fo_ref = fleet_hill_climb(ts, fleet)
    fp_got, fo_got = fleet_hill_climb(ts, fleet, objective=None)
    _check(
        fp_got.device_plans == fp_ref.device_plans and fo_got == fo_ref,
        "fleet_hill_climb(objective=None)",
    )
    _check(
        fleet_plan_objective(ts, fp_ref, fleet, objective=None)
        == fleet_plan_objective(ts, fp_ref, fleet),
        "fleet_plan_objective(objective=None)",
    )

    # Cache path: the default keyspace is the pinned pre-refactor 5-tuple
    # and lookups under objective=None hit entries stored without one.
    cache = PlanCache()
    _check(
        cache._key(ts, HW, K_MAX, None, objective=None)
        == cache._key(ts, HW, K_MAX, None),
        "PlanCache default key (objective=None)",
    )
    _check(
        len(cache._key(ts, HW, K_MAX, None)) == 5,
        "PlanCache default keyspace width",
    )
    cache.store(ts, HW, K_MAX, plan, obj)
    hit = cache.lookup(ts, HW, K_MAX, objective=None)
    _check(
        hit is not None and hit[0] == plan,
        "PlanCache lookup(objective=None)",
    )

    # Controller path: explicit Nones commit identical plans and produce
    # bitwise-identical latencies.
    profs = [t.profile for t in ts]
    tr = _trace(150.0, seed + 10)
    common = dict(replan_period=30.0, window=30.0, initial_rates=RATES)
    ref_run = run_adaptive(profs, tr, HW, K_MAX, **common)
    got_run = run_adaptive(
        profs,
        tr,
        HW,
        K_MAX,
        objective=None,
        rate_margin=None,
        deadlines=None,
        **common,
    )
    _check(got_run.plans == ref_run.plans, "run_adaptive committed plans")
    for i in range(len(profs)):
        _check(
            np.array_equal(
                np.asarray(ref_run.sim.latencies[i]),
                np.asarray(got_run.sim.latencies[i]),
            ),
            f"run_adaptive latencies (model {i})",
        )


# --------------------------------------------------------------------------
# The tail-sensitive sweep.
# --------------------------------------------------------------------------


def _plan_row(name, ts, plan, value, sim, deadlines) -> dict:
    misses = sim.per_model_deadline_miss_rate(list(deadlines))
    return {
        "plan": name,
        "partition": list(plan.partition),
        "cores": list(plan.cores),
        "planner_value": value,
        "p99_s": _pooled_p99(sim),
        "per_model_p99_s": sim.per_model_p99(),
        "mean_s": sim.overall_mean(),
        "deadline_miss_rate": sim.deadline_miss_rate(list(deadlines)),
        "per_model_miss_rate": misses,
        "analytic_mean_objective": latency.penalized_objective(ts, plan, HW),
    }


def run_sweep(*, smoke: bool = False, seed: int = 7) -> dict:
    self_check_mean_pin(seed)

    duration = 400.0 if smoke else 3000.0
    ts = _tenants()
    trace = _trace(duration, seed)

    plan_mean, v_mean = hill_climb(ts, HW, K_MAX)
    plan_tail, v_tail = hill_climb(ts, HW, K_MAX, objective=p_tail(0.99))
    # Cold deadline climb: honest failure mode (plateaued surface, greedy
    # gets stuck sacrificing the heavy tenant).  Warm-started from the mean
    # plan it escapes that basin.
    plan_dl_cold, v_dl_cold = hill_climb(
        ts, HW, K_MAX, objective=deadline_miss()
    )
    plan_dl, v_dl = hill_climb(
        ts, HW, K_MAX, objective=deadline_miss(), init_plan=plan_mean
    )

    rows = []
    for name, plan, value in (
        ("mean", plan_mean, v_mean),
        ("p_tail_0.99", plan_tail, v_tail),
        ("deadline_warm", plan_dl, v_dl),
        ("deadline_cold", plan_dl_cold, v_dl_cold),
    ):
        sim = simulate(ts, plan, HW, trace, backend="des")
        rows.append(_plan_row(name, ts, plan, value, sim, DEADLINES))

    by = {r["plan"]: r for r in rows}
    mean_row, tail_row = by["mean"], by["p_tail_0.99"]
    p99_gain = 100.0 * (1.0 - tail_row["p99_s"] / mean_row["p99_s"])
    mean_cost = 100.0 * (tail_row["mean_s"] / mean_row["mean_s"] - 1.0)
    return {
        "benchmark": "slo",
        "self_check": "objective_none_bitwise_pin_ok",
        "seed": seed,
        "duration_s": duration,
        "trace_requests": len(trace),
        "models": list(MODELS),
        "rates": list(RATES),
        "deadlines_s": list(DEADLINES),
        "burst": {
            "burst_factor": BURST_FACTOR,
            "mean_normal_s": MEAN_NORMAL_S,
            "mean_burst_s": MEAN_BURST_S,
        },
        "plans": rows,
        "headline": {
            "p99_gain_pct": p99_gain,
            "p99_gain_target_pct": P99_GAIN_TARGET_PCT,
            "mean_given_up_pct": mean_cost,
            "mean_plan_miss_rate": mean_row["deadline_miss_rate"],
            "tail_plan_miss_rate": tail_row["deadline_miss_rate"],
            "deadline_cold_vs_warm_value": [
                by["deadline_cold"]["planner_value"],
                by["deadline_warm"]["planner_value"],
            ],
        },
    }


def _rows_of(report: dict) -> list[Row]:
    mean_p99 = next(
        r["p99_s"] for r in report["plans"] if r["plan"] == "mean"
    )
    rows = []
    for r in report["plans"]:
        gain = 100.0 * (1.0 - r["p99_s"] / mean_p99)
        miss = r["deadline_miss_rate"]
        rows.append(
            Row(
                f"slo/{r['plan']}",
                r["mean_s"] * 1e6,
                f"p99_ms={r['p99_s']*1e3:.1f};"
                f"p99_gain_pct={gain:.1f};"
                f"miss_rate={miss:.4f}"
                if math.isfinite(miss)
                else f"p99_ms={r['p99_s']*1e3:.1f};p99_gain_pct={gain:.1f}",
            )
        )
    return rows


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(run_sweep(smoke=True))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short trace: CI sanity (self-check + shape), not a record",
    )
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args()
    report = run_sweep(smoke=args.smoke, seed=args.seed)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    h = report["headline"]
    print(
        f"# headline: p_tail(0.99) plan cuts pooled p99 "
        f"{h['p99_gain_pct']:.1f}% vs the mean plan "
        f"(target >= {h['p99_gain_target_pct']:.0f}%), miss rate "
        f"{h['mean_plan_miss_rate']:.4f} -> {h['tail_plan_miss_rate']:.4f}, "
        f"giving up {h['mean_given_up_pct']:.1f}% mean latency"
    )
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
