"""Fleet-scale plan search vs naive placement: the N-device sweep.

Quantifies what the two-level fleet planner (``repro.core.fleet``) buys
over naive placement, and that fleet re-planning stays inside the
controller's latency budget as the fleet grows:

* **Placement quality** -- an 8-tenant paper-model mix on a 4-device
  heterogeneous fleet (fast/reference/small/tiny device classes: distinct
  SRAM, swap bandwidth, core counts, and TPU/CPU speed factors).
  ``fleet_hill_climb`` (load-balanced packing + per-device climbs + the
  migration improvement loop) is simulated head-to-head against
  ``round_robin_fleet_plan`` (tenant ``i`` on device ``i % N``, then the
  *same* per-device hill climb -- so the comparison isolates the placement
  decision).  The headline is the simulated request-weighted mean-latency
  reduction; the acceptance bar is >= 20%.
* **Re-plan latency** -- a 64-device x 64-tenant fleet: cold plan (packing
  + improvement loop) and the controller-path *warm* re-plan (placement
  fixed, N warm per-device climbs against class-shared ``PlanTables``)
  after a rate drift.  The acceptance bar is warm < 250 ms.

Before anything is timed, the N=1 degenerate case is self-checked: a
single-device unit-speed fleet must reproduce ``hill_climb``'s plan and
``simulate``'s result **bitwise** (the ROADMAP fleet invariant) -- a sweep
whose degenerate case drifted from the single-device reference would be
meaningless.

Usage:
    PYTHONPATH=src python -m benchmarks.fleet_scaling [--smoke]
        [--duration SEC] [--seed N] [--out BENCH_fleet_scaling.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import HW, Row
from repro.configs.paper_models import paper_profile
from repro.core.allocator import hill_climb
from repro.core.fleet import (
    DeviceSpec,
    FleetTablesCache,
    fleet_hill_climb,
    round_robin_fleet_plan,
    validate_fleet_plan,
)
from repro.core.planner import TenantSpec
from repro.serving.fleet import simulate_fleet
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

# The 4-device heterogeneous mix: two full-spec boxes (one overclocked),
# one mid-tier and one weak device (half/quarter SRAM and swap bandwidth,
# two cores, slower TPU and CPU).  Round-robin placement lands two of the
# eight tenants on each regardless of capability -- the gap the planner
# must close.
def hetero_fleet() -> list[DeviceSpec]:
    return [
        DeviceSpec("fast", 8 << 20, 400e6, 4, tpu_speed=1.2),
        DeviceSpec("ref", 8 << 20, 400e6, 4),
        DeviceSpec("small", 4 << 20, 200e6, 2, tpu_speed=0.6, cpu_speed=0.7),
        DeviceSpec("tiny", 2 << 20, 100e6, 2, tpu_speed=0.4, cpu_speed=0.5),
    ]


TENANT_NAMES = [
    "squeezenet",
    "mobilenetv2",
    "efficientnet",
    "mnasnet",
    "gpunet",
    "densenet201",
    "resnet50v2",
    "xception",
]


def tenant_mix() -> list[TenantSpec]:
    # Rates climb with model size: the heavy tenants carry the most traffic,
    # so round-robin's blind spreading parks hot heavyweights on the weak
    # devices -- exactly the gap placement search must close.  Round-robin
    # stays stable (finite latencies), so the win percentage is meaningful.
    return [
        TenantSpec(paper_profile(n), 2.0 + 0.5 * i)
        for i, n in enumerate(TENANT_NAMES)
    ]


def self_check_degenerate(tenants, trace) -> None:
    """N=1 unit-speed fleet == the single-device API, bitwise."""
    dev = DeviceSpec.from_platform(HW, cpu_cores=len(tenants))
    fleet_plan, fleet_obj = fleet_hill_climb(tenants, [dev])
    plan, obj = hill_climb(tenants, HW, len(tenants))
    if fleet_plan.device_plans[0] != plan or fleet_obj != obj:
        raise AssertionError(
            "N=1 fleet_hill_climb drifted from hill_climb: "
            f"{fleet_plan.device_plans[0]} vs {plan}"
        )
    ref = simulate(tenants, plan, HW, trace)
    got = simulate_fleet(tenants, fleet_plan, [dev], trace)
    for i in range(len(tenants)):
        if not np.array_equal(
            np.asarray(ref.latencies[i]), np.asarray(got.latencies[i])
        ):
            raise AssertionError(f"N=1 simulate_fleet drifted (model {i})")
    if (
        ref.misses != got.misses
        or ref.tpu_requests != got.tpu_requests
        or ref.tpu_busy != got.tpu_busy
        or ref.duration != got.duration
    ):
        raise AssertionError("N=1 simulate_fleet counters drifted")


def placement_quality(duration: float, seed: int) -> dict:
    tenants = tenant_mix()
    fleet = hetero_fleet()
    rates = [t.rate for t in tenants]
    trace = poisson_trace(rates, duration, seed=seed)

    t0 = time.perf_counter()
    fleet_plan, fleet_obj = fleet_hill_climb(tenants, fleet)
    plan_seconds = time.perf_counter() - t0
    rr_plan, rr_obj = round_robin_fleet_plan(tenants, fleet)
    validate_fleet_plan(fleet_plan, tenants, fleet)
    validate_fleet_plan(rr_plan, tenants, fleet)

    res_fleet = simulate_fleet(tenants, fleet_plan, fleet, trace)
    res_rr = simulate_fleet(tenants, rr_plan, fleet, trace)
    mean_fleet = res_fleet.request_weighted_mean(rates)
    mean_rr = res_rr.request_weighted_mean(rates)
    win_pct = 100.0 * (1.0 - mean_fleet / mean_rr)
    return {
        "n_devices": len(fleet),
        "n_tenants": len(tenants),
        "trace_requests": len(trace),
        "planner_mean_s": mean_fleet,
        "round_robin_mean_s": mean_rr,
        "planner_p99_s": max(
            res_fleet.p99(i) for i in range(len(tenants))
        ),
        "round_robin_p99_s": max(res_rr.p99(i) for i in range(len(tenants))),
        "win_pct": win_pct,
        "plan_seconds": plan_seconds,
        "placement": [p[0] for p in fleet_plan.placement],
        "rr_placement": [p[0] for p in rr_plan.placement],
        "planner_tpu_utilization": res_fleet.tpu_utilization,
        "round_robin_tpu_utilization": res_rr.tpu_utilization,
    }


def replan_scaling(n_devices: int, n_tenants: int) -> dict:
    """Cold vs warm fleet re-plan wall time at (n_devices, n_tenants)."""
    classes = hetero_fleet()
    fleet = [
        DeviceSpec(
            f"d{i}",
            classes[i % 4].sram_bytes,
            classes[i % 4].swap_bw,
            classes[i % 4].cpu_cores,
            tpu_speed=classes[i % 4].tpu_speed,
            cpu_speed=classes[i % 4].cpu_speed,
        )
        for i in range(n_devices)
    ]
    tenants = [
        TenantSpec(
            paper_profile(TENANT_NAMES[i % len(TENANT_NAMES)]),
            1.0 + 0.1 * (i % 7),
        )
        for i in range(n_tenants)
    ]
    cache = FleetTablesCache()
    t0 = time.perf_counter()
    cold_plan, _ = fleet_hill_climb(tenants, fleet, tables=cache)
    cold_s = time.perf_counter() - t0
    # The controller path: rates drifted, placement held, N warm climbs.
    drifted = [TenantSpec(t.profile, t.rate * 1.15) for t in tenants]
    t0 = time.perf_counter()
    warm_plan, _ = fleet_hill_climb(
        drifted, fleet, init=cold_plan, tables=cache
    )
    warm_s = time.perf_counter() - t0
    validate_fleet_plan(warm_plan, drifted, fleet)
    return {
        "n_devices": n_devices,
        "n_tenants": n_tenants,
        "cold_ms": cold_s * 1e3,
        "warm_ms": warm_s * 1e3,
    }


def run_sweep(*, duration: float = 200.0, seed: int = 5) -> dict:
    check_trace = poisson_trace(
        [t.rate for t in tenant_mix()[:4]], min(duration, 120.0), seed=seed + 1
    )
    self_check_degenerate(tenant_mix()[:4], check_trace)

    quality = placement_quality(duration, seed)
    scaling = [
        replan_scaling(4, 8),
        replan_scaling(16, 32),
        replan_scaling(64, 64),
    ]
    big = scaling[-1]
    return {
        "benchmark": "fleet_scaling",
        "self_check": "n1_degenerate_bitwise_ok",
        "quality": quality,
        "replan_scaling": scaling,
        "headline": {
            "win_pct_vs_round_robin": quality["win_pct"],
            "win_target_pct": 20.0,
            "replan_64x64_warm_ms": big["warm_ms"],
            "replan_target_ms": 250.0,
        },
    }


def _rows_of(report: dict) -> list[Row]:
    q = report["quality"]
    rows = [
        Row(
            f"fleet_scaling/placement/{q['n_devices']}dev_{q['n_tenants']}ten",
            q["planner_mean_s"] * 1e6,
            f"win_vs_rr_pct={q['win_pct']:.1f};"
            f"rr_mean_ms={q['round_robin_mean_s']*1e3:.1f};"
            f"util={q['planner_tpu_utilization']:.3f}",
        )
    ]
    rows += [
        Row(
            f"fleet_scaling/replan/{s['n_devices']}dev_{s['n_tenants']}ten",
            s["warm_ms"] * 1e3,
            f"cold_ms={s['cold_ms']:.1f};warm_ms={s['warm_ms']:.1f}",
        )
        for s in report["replan_scaling"]
    ]
    return rows


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(run_sweep(duration=120.0))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short traces: CI sanity (self-check + shape), not a record",
    )
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--out", default="BENCH_fleet_scaling.json")
    args = ap.parse_args()
    duration = args.duration if args.duration is not None else (
        120.0 if args.smoke else 600.0
    )
    report = run_sweep(duration=duration, seed=args.seed)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    h = report["headline"]
    print(
        f"# headline: fleet planner cuts mean latency "
        f"{h['win_pct_vs_round_robin']:.1f}% vs round-robin placement "
        f"(target >= {h['win_target_pct']:.0f}%); 64x64 warm re-plan "
        f"{h['replan_64x64_warm_ms']:.1f} ms "
        f"(target < {h['replan_target_ms']:.0f} ms)"
    )
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
