"""Fig. 8 reproduction: dynamic workload adaptation.

MnasNet + InceptionV4; rates (5,1) RPS -> (5,3) at 300 s -> (5,5) at 600 s.
SwapLess re-plans online from sliding-window rate estimates; baselines keep
their static plans.  Paper headline: up to 75.1% reduction vs static
allocation; allocator overhead < 2 ms per invocation.
"""
from __future__ import annotations

from benchmarks.common import HW, K_MAX, Row, tenants
from repro.configs.paper_models import paper_profile
from repro.core.allocator import (
    edge_tpu_compiler_plan,
    swapless_plan,
    threshold_plan,
)
from repro.serving.controller import run_adaptive
from repro.serving.simulator import simulate
from repro.serving.workload import RatePhase, dynamic_trace

PHASES = [
    RatePhase(0.0, 300.0, (5.0, 1.0)),
    RatePhase(300.0, 600.0, (5.0, 3.0)),
    RatePhase(600.0, 900.0, (5.0, 5.0)),
]


def run() -> list[Row]:
    rows = []
    profs = [paper_profile("mnasnet"), paper_profile("inceptionv4")]
    trace = dynamic_trace(PHASES, seed=5)

    # warmup_frac matches simulate()'s default so the adaptive and static
    # rows below exclude the same cold-start cache fills.
    res = run_adaptive(
        profs, trace, HW, K_MAX,
        replan_period=30.0, window=30.0, initial_rates=(5.0, 1.0),
        warmup_frac=0.05,
    )
    adaptive_lat = res.sim.overall_mean()
    max_plan_ms = max(res.plan_compute_seconds) * 1e3
    rows.append(
        Row(
            "fig8/adaptive",
            adaptive_lat * 1e6,
            f"replans={len(res.plans)};max_alloc_ms={max_plan_ms:.2f} (paper <2ms)",
        )
    )

    # Static baselines planned for the initial rates.
    ts0 = tenants(profs, [5.0, 1.0])
    best_red = 0.0
    for name, plan in [
        ("static_compiler", edge_tpu_compiler_plan(ts0)),
        ("static_threshold", threshold_plan(ts0, HW, K_MAX)),
        ("static_swapless_initial", swapless_plan(ts0, HW, K_MAX)),
    ]:
        sim = simulate(ts0, plan, HW, trace)
        lat = sim.overall_mean()
        red = 100.0 * (lat - adaptive_lat) / lat if lat > 0 else 0.0
        best_red = max(best_red, red)
        rows.append(
            Row(
                f"fig8/{name}",
                lat * 1e6,
                f"adaptive_reduction_pct={red:.1f}",
            )
        )
    rows.append(
        Row("fig8/summary", adaptive_lat * 1e6,
            f"best_reduction_vs_static_pct={best_red:.1f} (paper 75.1)")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
