"""Fig. 5 reproduction: single-tenant analytic-model validation.

(a) InceptionV4 at rho=0.2 across partition points: predicted vs observed
    (DES) mean latency; paper reports MAPE 1.9%, 92.3% within +/-5%.
(b) across request rates: the optimal partition point shifts with load
    (paper: PP9 below ~4.5 RPS, PP7 above).
"""
from __future__ import annotations

from benchmarks.common import HW, K_MAX, Row, mape, tenants
from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import prop_alloc
from repro.core.planner import Plan, prefix_service_time
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

DURATION = 3000.0


def _plan_for_pp(ts, pp):
    P = ts[0].profile.num_partition_points
    cores = prop_alloc(ts, [pp], K_MAX)
    return Plan((pp,), cores)


def run() -> list[Row]:
    rows = []
    prof = paper_profile("inceptionv4")
    P = prof.num_partition_points
    s_full = prefix_service_time(prof, P, HW)
    rate_rho02 = 0.2 / s_full

    # (a) across partition points at rho = 0.2.
    preds, obss = [], []
    for pp in range(0, P + 1):
        ts = tenants([prof], [rate_rho02])
        plan = _plan_for_pp(ts, pp)
        pred = latency.predict(ts, plan, HW)
        if pred.tpu_utilization >= 1.0 or not pred.stable:
            continue
        reqs = poisson_trace([rate_rho02], DURATION, seed=pp)
        sim = simulate(ts, plan, HW, reqs)
        p, o = pred.latencies[0], sim.mean_latency(0)
        preds.append(p)
        obss.append(o)
        rows.append(
            Row(
                name=f"fig5a/inceptionv4/pp{pp}",
                us_per_call=o * 1e6,
                derived=f"pred_us={p*1e6:.0f};err_pct={100*abs(p-o)/o:.1f}",
            )
        )
    m = mape(preds, obss)
    within5 = 100.0 * sum(
        1 for p, o in zip(preds, obss) if abs(p - o) / o <= 0.05
    ) / len(preds)
    rows.append(
        Row(
            name="fig5a/summary",
            us_per_call=0.0,
            derived=f"mape_pct={m:.1f};within5_pct={within5:.0f};paper_mape=1.9",
        )
    )

    # (b) across request rates: which PP is optimal?
    for rps in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        ts = tenants([prof], [rps])
        best_pp, best_lat = None, float("inf")
        for pp in range(0, P + 1):
            plan = _plan_for_pp(ts, pp)
            pred = latency.predict(ts, plan, HW)
            if not pred.stable:
                continue
            if pred.latencies[0] < best_lat:
                best_lat = pred.latencies[0]
                best_pp = pp
        rows.append(
            Row(
                name=f"fig5b/inceptionv4/rps{rps:.0f}",
                us_per_call=best_lat * 1e6,
                derived=f"optimal_pp={best_pp}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
