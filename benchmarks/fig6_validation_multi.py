"""Fig. 6 reproduction: multi-tenant analytic-model validation.

(a) alpha across mixes (fit -> 0; 50:50 -> 0.5; 90:10 -> 0.9/0.1) vs the
    DES's observed miss rates.
(b) predicted vs observed latency across model mixes (paper MAPE 6.8%).
(c) accuracy across request rates for one mix.
"""
from __future__ import annotations

from benchmarks.common import HW, Row, mape, tenants
from repro.configs.paper_models import paper_profile
from repro.core import latency, swap
from repro.core.allocator import edge_tpu_compiler_plan
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

DURATION = 3000.0

ALPHA_SCENARIOS = [
    ("mobilenetv2+squeezenet", ["mobilenetv2", "squeezenet"], (2.0, 2.0)),
    ("efficientnet+gpunet_50:50", ["efficientnet", "gpunet"], (2.0, 2.0)),
    ("efficientnet+gpunet_90:10", ["efficientnet", "gpunet"], (3.6, 0.4)),
]

MIXES = [
    ("mobilenet+squeeze", ["mobilenetv2", "squeezenet"]),
    ("efficient+gpunet", ["efficientnet", "gpunet"]),
    ("densenet+gpunet", ["densenet201", "gpunet"]),
    ("mnasnet+gpunet", ["mnasnet", "gpunet"]),
    ("efficient+mnasnet+gpunet", ["efficientnet", "mnasnet", "gpunet"]),
]


def run() -> list[Row]:
    rows = []
    # (a) alpha validation.
    for name, names, rates in ALPHA_SCENARIOS:
        profs = [paper_profile(n) for n in names]
        ts = tenants(profs, rates)
        plan = edge_tpu_compiler_plan(ts)
        alphas = swap.weight_miss_probs(ts, plan.partition, HW)
        sim = simulate(ts, plan, HW, poisson_trace(list(rates), DURATION, seed=1))
        for i, n in enumerate(names):
            rows.append(
                Row(
                    name=f"fig6a/{name}/{n}",
                    us_per_call=sim.mean_latency(i) * 1e6,
                    derived=(
                        f"alpha={alphas[i]:.2f};"
                        f"observed_miss={sim.observed_miss_rate(i):.2f}"
                    ),
                )
            )

    # (b) latency prediction across mixes (equal TPU load per model).
    preds, obss = [], []
    for mix_name, names in MIXES:
        profs = [paper_profile(n) for n in names]
        from benchmarks.common import full_tpu_rates_for_utilization

        rates = full_tpu_rates_for_utilization(profs, 0.5)
        ts = tenants(profs, rates)
        plan = edge_tpu_compiler_plan(ts)
        pred = latency.predict(ts, plan, HW)
        sim = simulate(ts, plan, HW, poisson_trace(rates, DURATION, seed=2))
        p = pred.mean_latency(ts)
        o = sim.overall_mean()
        preds.append(p)
        obss.append(o)
        rows.append(
            Row(
                name=f"fig6b/{mix_name}",
                us_per_call=o * 1e6,
                derived=f"pred_us={p*1e6:.0f};err_pct={100*abs(p-o)/o:.1f}",
            )
        )
    rows.append(
        Row(
            name="fig6b/summary",
            us_per_call=0.0,
            derived=f"mape_pct={mape(preds, obss):.1f};paper_mape=6.8",
        )
    )

    # (c) across request rates for efficientnet+gpunet.
    profs = [paper_profile("efficientnet"), paper_profile("gpunet")]
    preds, obss = [], []
    for rho in (0.2, 0.35, 0.5, 0.65):
        from benchmarks.common import full_tpu_rates_for_utilization

        rates = full_tpu_rates_for_utilization(profs, rho)
        ts = tenants(profs, rates)
        plan = edge_tpu_compiler_plan(ts)
        pred = latency.predict(ts, plan, HW).mean_latency(ts)
        sim = simulate(ts, plan, HW, poisson_trace(rates, DURATION, seed=3))
        obs = sim.overall_mean()
        preds.append(pred)
        obss.append(obs)
        rows.append(
            Row(
                name=f"fig6c/rho{rho:.2f}",
                us_per_call=obs * 1e6,
                derived=f"pred_us={pred*1e6:.0f};err_pct={100*abs(pred-obs)/obs:.1f}",
            )
        )
    rows.append(
        Row(
            name="fig6c/summary",
            us_per_call=0.0,
            derived=f"mape_pct={mape(preds, obss):.1f}",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
