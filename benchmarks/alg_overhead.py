"""Section V-D reproduction: allocator invocation overhead.

Paper claim: the greedy hill-climbing allocation runs in < 2 ms.
"""
from __future__ import annotations

import time

from benchmarks.common import HW, K_MAX, Row, full_tpu_rates_for_utilization, tenants
from repro.configs.paper_models import paper_profile
from repro.core.allocator import hill_climb

CASES = [
    ("n2", ["mnasnet", "inceptionv4"]),
    ("n3", ["mobilenetv2", "gpunet", "inceptionv4"]),
    ("n4", ["mobilenetv2", "efficientnet", "xception", "inceptionv4"]),
]


def run() -> list[Row]:
    rows = []
    for name, names in CASES:
        profs = [paper_profile(n) for n in names]
        rates = full_tpu_rates_for_utilization(profs, 0.5)
        ts = tenants(profs, rates)
        # hill_climb auto-dispatches by mix size; at the paper's 2-4 tenant
        # testbed that is the scalar path (the batched engine wins from ~5
        # tenants up -- see alg_scaling for the scaling sweep).
        hill_climb(ts, HW, K_MAX)  # warm-up
        n_iter = 20
        t0 = time.perf_counter()
        for _ in range(n_iter):
            hill_climb(ts, HW, K_MAX)
        dt = (time.perf_counter() - t0) / n_iter
        rows.append(
            Row(
                f"alg_overhead/{name}",
                dt * 1e6,
                f"ms_per_invocation={dt*1e3:.2f} (paper <2ms)",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
