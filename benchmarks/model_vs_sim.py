"""Analytic queueing model vs discrete-event simulator: error sweep.

Sweeps the workload scenario library (Poisson, bursty MMPP, diurnal,
heavy-tailed service jitter, tenant churn) across representative tenant
mixes and reports, per combination, the analytic model's (Eq. 1-5, Eq. 10)
mean-latency error against the event-driven ground truth plus a
cross-simulator p99 check (DES vs the sequential stepper), and -- since the
SLO objective layer -- the analytic *tail* model's p99 error and the
analytic deadline-miss error against the observed miss fractions, so the
M/G/1 exponential-tail approximation's error map is tracked alongside the
mean's (where the tail approximation breaks, ``p99_err_pct`` shows it).

The analytic prediction is evaluated at the *realized* mean per-model rates
of each trace -- what a long-window rate estimator would hand the planner --
so the reported error isolates model-shape mismatch (burstiness, service
variance, nonstationarity) from plain rate misestimation.  See
``benchmarks/README.md`` for how to read the numbers.

Usage:
    PYTHONPATH=src python -m benchmarks.model_vs_sim [--smoke] [--seed N]
"""
from __future__ import annotations

import argparse
import math
from typing import Callable, Sequence

import numpy as np

from benchmarks.common import (
    HW,
    K_MAX,
    Row,
    full_tpu_rates_for_utilization,
    mape,
    tenants,
)
from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.allocator import hill_climb
from repro.core.planner import Plan, TenantSpec
from repro.serving.faults import FaultEvent, FaultSchedule
from repro.serving.simulator import simulate
from repro.serving.workload import (
    Trace,
    diurnal_trace,
    mmpp_trace,
    poisson_trace,
    tenant_churn_trace,
    with_service_jitter,
)

TraceFn = Callable[[list[float], float, int], Trace]

# Poisson is the model's home turf (its arrival assumption holds exactly);
# every other scenario violates one assumption on purpose.
SCENARIOS: dict[str, TraceFn] = {
    "poisson": lambda rates, dur, seed: poisson_trace(rates, dur, seed=seed),
    "mmpp": lambda rates, dur, seed: mmpp_trace(
        rates, dur, burst_factor=3.0, mean_normal=40.0, mean_burst=10.0, seed=seed
    ),
    "diurnal": lambda rates, dur, seed: diurnal_trace(
        rates, dur, amplitude=0.6, period=dur / 4.0, seed=seed
    ),
    "jitter": lambda rates, dur, seed: with_service_jitter(
        poisson_trace(rates, dur, seed=seed), sigma=0.8, seed=seed + 1
    ),
    "churn": lambda rates, dur, seed: tenant_churn_trace(
        rates, dur, mean_session=dur / 4.0, mean_absence=dur / 8.0, seed=seed
    ).requests,
}


def _mixes() -> list[tuple[str, list[TenantSpec], Plan]]:
    """Representative tenant mixes: swap-free, swap-dominated, collaborative."""
    iv4, mnas = paper_profile("inceptionv4"), paper_profile("mnasnet")
    mob, sq = paper_profile("mobilenetv2"), paper_profile("squeezenet")
    eff, gpu = paper_profile("efficientnet"), paper_profile("gpunet")

    mixes = []
    ts = tenants([iv4], full_tpu_rates_for_utilization([iv4], 0.6))
    mixes.append(("single_full_tpu", ts, Plan((11,), (0,))))

    ts = tenants([mob, sq], full_tpu_rates_for_utilization([mob, sq], 0.5))
    mixes.append(("pair_sram_fits", ts, Plan((5, 2), (0, 0))))

    ts = tenants([eff, gpu], full_tpu_rates_for_utilization([eff, gpu], 0.5))
    mixes.append(("pair_swapping", ts, Plan((6, 5), (0, 0))))

    ts = [TenantSpec(iv4, 1.0), TenantSpec(mnas, 2.0)]
    plan, _ = hill_climb(ts, HW, K_MAX)
    mixes.append(("collaborative", ts, plan))
    return mixes


def _slo_columns(
    ts_real: Sequence[TenantSpec], plan: Plan, des
) -> str:
    """Analytic-vs-DES tail columns: p99 MAPE and deadline-miss error.

    The deadline-miss probe sets each tenant's budget at twice its
    analytically predicted mean (a budget the mean plan roughly meets, so
    both sides produce informative, non-saturated miss rates); the error is
    the mean absolute miss-probability gap in percentage points -- MAPE is
    useless when the observed rate is legitimately 0.
    """
    n = len(ts_real)
    pred = latency.predict(ts_real, plan, HW)
    tail_pred = latency.predict_tail_latencies(ts_real, plan, HW, 0.99, pred=pred)
    p99_err = mape(list(tail_pred), [des.p99(i) for i in range(n)])
    deadlines = [
        2.0 * m if math.isfinite(m) else math.inf for m in pred.latencies
    ]
    miss_pred = latency.predict_miss_probs(
        ts_real, plan, HW, np.asarray(deadlines), pred=pred
    )
    miss_obs = des.per_model_deadline_miss_rate(deadlines)
    pairs = [
        (p, o)
        for p, o in zip(miss_pred, miss_obs)
        if math.isfinite(p) and math.isfinite(o)
    ]
    miss_err = (
        100.0 * sum(abs(p - o) for p, o in pairs) / len(pairs)
        if pairs
        else math.nan
    )
    return f"p99_err_pct={p99_err:.1f};miss_err_pp={miss_err:.1f}"


def _realized_tenants(
    base: Sequence[TenantSpec], trace: Trace, duration: float
) -> list[TenantSpec]:
    counts = np.bincount(trace.model_idx, minlength=len(base))
    return [
        TenantSpec(t.profile, int(c) / duration) for t, c in zip(base, counts)
    ]


# Fault injection breaks the analytic model's stationarity assumption on
# purpose: the model predicts the *nominal* steady state, so its error
# under each fault quantifies how much a fault-oblivious prediction
# misleads (the numbers fault-aware re-planning acts on instead).  The DES
# and the stepper must still agree under every fault -- the cross-sim
# column is the injected-fault parity evidence.
def _fault_scenarios(duration: float) -> dict[str, FaultSchedule]:
    s, e = 0.3 * duration, 0.6 * duration
    return {
        "fault_dropout": FaultSchedule(
            events=(
                FaultEvent(kind="dropout", device=0, start=s, end=e),
            ),
            dropout_policy="requeue",
        ),
        "fault_throttle": FaultSchedule(
            events=(
                FaultEvent(
                    kind="throttle",
                    device=0,
                    start=s,
                    end=e,
                    tpu_factor=0.3,
                    cpu_factor=0.3,
                ),
            ),
        ),
        "fault_swap": FaultSchedule(
            events=(
                FaultEvent(
                    kind="swap_degrade",
                    device=0,
                    start=s,
                    end=e,
                    swap_factor=0.1,
                ),
            ),
        ),
    }


def _fault_rows(duration: float, seed: int) -> list[Row]:
    """Analytic-model error and DES/stepper parity under injected faults
    (collaborative mix, Poisson arrivals -- the model's home turf, so any
    error growth is attributable to the fault alone)."""
    iv4, mnas = paper_profile("inceptionv4"), paper_profile("mnasnet")
    ts = [TenantSpec(iv4, 1.0), TenantSpec(mnas, 2.0)]
    plan, _ = hill_climb(ts, HW, K_MAX)
    rates = [t.rate for t in ts]
    trace = poisson_trace(rates, duration, seed=seed)
    rows = []
    for name, faults in _fault_scenarios(duration).items():
        des = simulate(ts, plan, HW, trace, backend="des", faults=faults)
        stepper = simulate(
            ts, plan, HW, trace, backend="stepper", faults=faults
        )
        ts_real = _realized_tenants(ts, trace, duration)
        pred = latency.predict(ts_real, plan, HW)
        obs_means = [des.mean_latency(i) for i in range(len(ts))]
        mean_err = mape(pred.latencies, obs_means)
        p99s = [des.p99(i) for i in range(len(ts))]
        p99_xsim = mape([stepper.p99(i) for i in range(len(ts))], p99s)
        finite_p99 = [p for p in p99s if math.isfinite(p)]
        worst_p99_ms = max(finite_p99) * 1e3 if finite_p99 else math.nan
        rows.append(
            Row(
                f"model_vs_sim/collaborative/{name}",
                des.overall_mean() * 1e6,
                f"mean_err_pct={mean_err:.1f};p99_ms={worst_p99_ms:.1f};"
                f"p99_xsim_err_pct={p99_xsim:.1f};"
                f"{_slo_columns(ts_real, plan, des)};n={len(trace)};"
                f"lost={des.requests_lost};requeued={des.requests_requeued}",
            )
        )
    return rows


def run(*, duration: float = 2000.0, seed: int = 0) -> list[Row]:
    rows: list[Row] = []
    for mix_name, ts, plan in _mixes():
        rates = [t.rate for t in ts]
        for scen_name, make_trace in SCENARIOS.items():
            trace = make_trace(rates, duration, seed)
            if not trace:
                continue
            des = simulate(ts, plan, HW, trace, backend="des")
            stepper = simulate(ts, plan, HW, trace, backend="stepper")
            ts_real = _realized_tenants(ts, trace, duration)
            pred = latency.predict(ts_real, plan, HW)

            obs_means = [des.mean_latency(i) for i in range(len(ts))]
            mean_err = mape(pred.latencies, obs_means)
            p99s = [des.p99(i) for i in range(len(ts))]
            p99_xsim = mape([stepper.p99(i) for i in range(len(ts))], p99s)
            finite_p99 = [p for p in p99s if math.isfinite(p)]
            worst_p99_ms = max(finite_p99) * 1e3 if finite_p99 else math.nan
            rows.append(
                Row(
                    f"model_vs_sim/{mix_name}/{scen_name}",
                    des.overall_mean() * 1e6,
                    f"mean_err_pct={mean_err:.1f};p99_ms={worst_p99_ms:.1f};"
                    f"p99_xsim_err_pct={p99_xsim:.1f};"
                    f"{_slo_columns(ts_real, plan, des)};n={len(trace)}",
                )
            )
    rows.extend(_fault_rows(duration, seed))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short traces for CI sanity (smaller n, larger CI error bars)",
    )
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    duration = args.duration if args.duration is not None else (
        300.0 if args.smoke else 2000.0
    )
    print("name,us_per_call,derived")
    for row in run(duration=duration, seed=args.seed):
        print(row.csv())


if __name__ == "__main__":
    main()
