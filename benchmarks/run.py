"""Benchmark harness: one module per paper table/figure or subsystem sweep.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run            # all benchmarks
    PYTHONPATH=src python -m benchmarks.run fig7       # one benchmark
"""
from __future__ import annotations

import importlib
import sys
import time

# name -> module path.  Resolved lazily: heavyweight modules (jax_throughput
# imports jax and pays its compilation cache) load only when selected, so
# `python -m benchmarks.run fig1` stays light.
MODULES = {
    "fig1": "benchmarks.fig1_intra_swap",
    "fig2": "benchmarks.fig2_inter_swap",
    "fig3": "benchmarks.fig3_segment_speedup",
    "fig5": "benchmarks.fig5_validation_single",
    "fig6": "benchmarks.fig6_validation_multi",
    "fig7": "benchmarks.fig7_baselines",
    "fig8": "benchmarks.fig8_dynamic",
    "alg_overhead": "benchmarks.alg_overhead",
    "alg_scaling": "benchmarks.alg_scaling",
    "alpha_ablation": "benchmarks.alpha_ablation",
    "model_vs_sim": "benchmarks.model_vs_sim",
    "scheduling": "benchmarks.scheduling",
    "sim_throughput": "benchmarks.sim_throughput",
    "jax_throughput": "benchmarks.jax_throughput",
    "fleet_scaling": "benchmarks.fleet_scaling",
    "predictive": "benchmarks.predictive",
    "faults": "benchmarks.faults",
    "slo": "benchmarks.slo",
}


def resolve(key: str):
    """Import the benchmark module registered under ``key``; a typo names
    every valid choice instead of dying on a bare KeyError."""
    try:
        path = MODULES[key]
    except KeyError:
        valid = ", ".join(sorted(MODULES))
        raise SystemExit(
            f"unknown benchmark {key!r}: valid benchmarks are {valid}"
        ) from None
    return importlib.import_module(path)


def main() -> None:
    selected = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for key in selected:
        mod = resolve(key)
        t0 = time.perf_counter()
        for row in mod.run():
            print(row.csv())
        dt = time.perf_counter() - t0
        print(f"{key}/_harness,{dt*1e6:.0f},wall_s={dt:.1f}", flush=True)


if __name__ == "__main__":
    main()
