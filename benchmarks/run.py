"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run            # all figures
    PYTHONPATH=src python -m benchmarks.run fig7       # one figure
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    alg_overhead,
    alg_scaling,
    alpha_ablation,
    fig1_intra_swap,
    fig2_inter_swap,
    fig3_segment_speedup,
    fig5_validation_single,
    fig6_validation_multi,
    fig7_baselines,
    fig8_dynamic,
    model_vs_sim,
    scheduling,
    sim_throughput,
)

MODULES = {
    "fig1": fig1_intra_swap,
    "fig2": fig2_inter_swap,
    "fig3": fig3_segment_speedup,
    "fig5": fig5_validation_single,
    "fig6": fig6_validation_multi,
    "fig7": fig7_baselines,
    "fig8": fig8_dynamic,
    "alg_overhead": alg_overhead,
    "alg_scaling": alg_scaling,
    "alpha_ablation": alpha_ablation,
    "model_vs_sim": model_vs_sim,
    "scheduling": scheduling,
    "sim_throughput": sim_throughput,
}


def main() -> None:
    selected = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for key in selected:
        mod = MODULES[key]
        t0 = time.perf_counter()
        for row in mod.run():
            print(row.csv())
        dt = time.perf_counter() - t0
        print(f"{key}/_harness,{dt*1e6:.0f},wall_s={dt:.1f}", flush=True)


if __name__ == "__main__":
    main()
