"""Fig. 2 reproduction: inter-model swapping overhead in multi-DNN mixes.

Paper claims: MobileNetV2+SqueezeNet fit -> no swapping; larger mixes lose
up to 35% (50:50) and up to 49% (90:10, for the rare model) of latency to
inter-model swaps.  Observed via the DES with the explicit SRAM cache,
compared against each model's standalone (single-tenant) execution.
"""
from __future__ import annotations

from benchmarks.common import HW, Row, tenants
from repro.configs.paper_models import paper_profile
from repro.core.allocator import edge_tpu_compiler_plan
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

MIXES = [
    ("mobilenetv2+squeezenet", ["mobilenetv2", "squeezenet"], (0.5, 0.5)),
    ("efficientnet+gpunet_50:50", ["efficientnet", "gpunet"], (0.5, 0.5)),
    ("efficientnet+gpunet_90:10", ["efficientnet", "gpunet"], (0.9, 0.1)),
    ("densenet+gpunet_50:50", ["densenet201", "gpunet"], (0.5, 0.5)),
]

TOTAL_RATE = 4.0
DURATION = 2000.0


def run() -> list[Row]:
    rows = []
    for mix_name, names, shares in MIXES:
        profs = [paper_profile(n) for n in names]
        rates = [TOTAL_RATE * s for s in shares]
        ts = tenants(profs, rates)
        plan = edge_tpu_compiler_plan(ts)
        reqs = poisson_trace(rates, DURATION, seed=42)
        sim = simulate(ts, plan, HW, reqs)
        for i, n in enumerate(names):
            # Standalone: same model alone at its rate (no inter-model swap).
            solo = simulate(
                tenants([profs[i]], [rates[i]]),
                edge_tpu_compiler_plan([ts[i]]),
                HW,
                poisson_trace([rates[i]], DURATION, seed=7),
            )
            mixed = sim.mean_latency(i)
            alone = solo.mean_latency(0)
            swap_pct = 100.0 * (mixed - alone) / mixed if mixed > 0 else 0.0
            rows.append(
                Row(
                    name=f"fig2/{mix_name}/{n}",
                    us_per_call=mixed * 1e6,
                    derived=(
                        f"inter_swap_pct={swap_pct:.1f};"
                        f"miss_rate={sim.observed_miss_rate(i):.2f}"
                    ),
                )
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
