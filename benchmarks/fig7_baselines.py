"""Fig. 7 reproduction: SwapLess vs baselines across mixes and utilization.

Policies: Edge TPU Compiler (all-TPU co-compilation), Threshold-based
partitioning, SwapLess (alpha=0), SwapLess.  All four plans are evaluated on
the same DES traces.  Paper headline: up to 63.8% (single-tenant) and 77.4%
(multi-tenant) mean-latency reduction vs the compiler baseline at rho=0.5.
"""
from __future__ import annotations

from benchmarks.common import HW, K_MAX, Row, full_tpu_rates_for_utilization, tenants
from repro.configs.paper_models import paper_profile
from repro.core.allocator import (
    edge_tpu_compiler_plan,
    swapless_alpha0_plan,
    swapless_plan,
    threshold_plan,
)
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

DURATION = 2500.0

SINGLE = ["mobilenetv2", "gpunet", "resnet50v2", "xception", "inceptionv4"]
MULTI = [
    ("mobilenetv2+squeezenet", ["mobilenetv2", "squeezenet"]),
    ("mobilenetv2+squeezenet+resnet", ["mobilenetv2", "squeezenet", "resnet50v2"]),
    ("efficientnet+gpunet", ["efficientnet", "gpunet"]),
    ("xception+inceptionv4", ["xception", "inceptionv4"]),
    ("densenet+resnet+gpunet", ["densenet201", "resnet50v2", "gpunet"]),
]

POLICIES = [
    ("compiler", lambda ts: edge_tpu_compiler_plan(ts)),
    ("threshold", lambda ts: threshold_plan(ts, HW, K_MAX)),
    ("swapless_a0", lambda ts: swapless_alpha0_plan(ts, HW, K_MAX)),
    ("swapless", lambda ts: swapless_plan(ts, HW, K_MAX)),
]


def _evaluate(scenario: str, names: list[str], rho: float, rows: list[Row]):
    profs = [paper_profile(n) for n in names]
    rates = full_tpu_rates_for_utilization(profs, rho)
    ts = tenants(profs, rates)
    reqs = poisson_trace(rates, DURATION, seed=13)
    base_lat = None
    for pol_name, pol in POLICIES:
        plan = pol(ts)
        sim = simulate(ts, plan, HW, reqs)
        lat = sim.overall_mean()
        if pol_name == "compiler":
            base_lat = lat
        red = 100.0 * (base_lat - lat) / base_lat if base_lat else 0.0
        rows.append(
            Row(
                name=f"fig7/{scenario}/rho{rho}/{pol_name}",
                us_per_call=lat * 1e6,
                derived=f"reduction_vs_compiler_pct={red:.1f};plan={list(plan.partition)}",
            )
        )


def run() -> list[Row]:
    rows: list[Row] = []
    best_single, best_multi = 0.0, 0.0
    for rho in (0.2, 0.5):
        for name in SINGLE:
            _evaluate(f"single/{name}", [name], rho, rows)
        for mix_name, names in MULTI:
            _evaluate(f"multi/{mix_name}", names, rho, rows)
    # Summaries.
    for r in rows:
        if not r.name.endswith("/swapless"):
            continue
        red = float(r.derived.split("reduction_vs_compiler_pct=")[1].split(";")[0])
        if "/single/" in r.name:
            best_single = max(best_single, red)
        else:
            best_multi = max(best_multi, red)
    rows.append(
        Row(
            "fig7/summary",
            0.0,
            f"best_single_reduction_pct={best_single:.1f} (paper 63.8);"
            f"best_multi_reduction_pct={best_multi:.1f} (paper 77.4)",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
