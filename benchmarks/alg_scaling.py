"""Plan-space engine scaling: batched vs seed scalar Algorithm 1, 2-16 tenants.

The vectorized evaluation engine (``latency.penalized_objective_batch`` over
``EvalTables``) scores every (m, h) move of a hill-climb iteration in one
NumPy pass, which turns the allocator's per-candidate Python cost into a
gather + row-sum.  This sweep measures both implementations on growing
tenant mixes and verifies they return identical plans.

Mixes beyond the paper's 4-model testbed model a beefier host
(K_max = max(4, n) cores); the paper platform's 4 cores cannot seat more
than 4 CPU suffixes, which is exactly the regime the batched engine opens.

Headline checks (CI-asserted by tests/test_batch_eval.py on small mixes):
  * identical plans at every size,
  * >= 5x speedup at 8 tenants,
  * < 100 ms per 16-tenant invocation.
"""
from __future__ import annotations

import time

from benchmarks.common import HW, Row, full_tpu_rates_for_utilization, tenants
from repro.configs.paper_models import PAPER_MODEL_NAMES, paper_profile
from repro.core.allocator import _hill_climb_scalar, hill_climb
from repro.core.plan_tables import PlanTables

SIZES = (2, 4, 8, 12, 16)
# Scalar cost grows ~quadratically in tenants; cap its reps to keep the
# sweep short while the batched side gets enough reps for stable numbers.
BATCH_REPS = 15
SCALAR_REPS = 4
ROUNDS = 3


def _mix(n: int):
    names = [PAPER_MODEL_NAMES[i % len(PAPER_MODEL_NAMES)] for i in range(n)]
    profs = [paper_profile(name) for name in names]
    rates = full_tpu_rates_for_utilization(profs, 0.5)
    return tenants(profs, rates)


def _best_of(fn, reps: int, rounds: int = ROUNDS) -> float:
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run() -> list[Row]:
    rows: list[Row] = []
    for n in SIZES:
        ts = _mix(n)
        k_max = max(HW.cpu.n_cores, n)
        # Identity first: the speedup claim only counts if plans agree.
        plan_b, obj_b = hill_climb(ts, HW, k_max, batch=True)
        plan_s, obj_s = _hill_climb_scalar(ts, HW, k_max)
        identical = plan_b == plan_s

        # Serving-loop conditions: the controller holds the rate-free tables
        # across re-plans, so the batched timing includes only the rate-aware
        # rebuild + climb.  The scalar path has no reusable state.
        tables = PlanTables.for_tenants(ts, HW, k_max)
        t_batch = _best_of(
            lambda: hill_climb(ts, HW, k_max, batch=True, tables=tables), BATCH_REPS
        )
        t_batch_cold = _best_of(lambda: hill_climb(ts, HW, k_max, batch=True), BATCH_REPS)
        t_scalar = _best_of(lambda: _hill_climb_scalar(ts, HW, k_max), SCALAR_REPS)
        rows.append(
            Row(
                f"alg_scaling/n{n}",
                t_batch * 1e6,
                f"speedup={t_scalar / t_batch:.1f}x "
                f"cold={t_scalar / t_batch_cold:.1f}x "
                f"scalar_ms={t_scalar * 1e3:.2f} "
                f"batch_ms={t_batch * 1e3:.2f} "
                f"identical_plans={identical}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
