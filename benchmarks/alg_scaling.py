"""Plan-space engine scaling: batched vs seed scalar Algorithm 1, plus the
incremental re-planning engine (warm start + delta evaluation), 2-64 tenants.

The vectorized evaluation engine (``latency.penalized_objective_batch`` over
``EvalTables``) scores every (m, h) move of a hill-climb iteration in one
NumPy pass; the incremental engine on top of it prices each neighbor move as
a delta against the current plan and warm-starts each re-plan from the
incumbent (``hill_climb(init_plan=...)``), which is the serving controller's
steady-state path.

Mixes beyond the paper's 4-model testbed model a beefier host
(K_max = max(4, n) cores); the paper platform's 4 cores cannot seat more
than 4 CPU suffixes, which is exactly the regime the batched engine opens.

Headline checks (CI-asserted by tests/test_batch_eval.py and
tests/test_replan.py on small mixes):
  * batched plans identical to the seed scalar reference at every size the
    scalar path can afford (n <= SCALAR_MAX_N),
  * >= 5x batch speedup at 8 tenants,
  * < 100 ms per re-plan at 32 tenants (cold and warm),
  * >= 3x warm-start speedup over the cold climb at 16+ tenants, with the
    warm plan tying or beating the cold objective (the warm search is a
    bidirectional local descent from the incumbent -- see allocator.py).

Usage: ``python -m benchmarks.alg_scaling [--tenants 32,64]``.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import HW, Row, full_tpu_rates_for_utilization, tenants
from repro.configs.paper_models import PAPER_MODEL_NAMES, paper_profile
from repro.core.allocator import _hill_climb_scalar, hill_climb
from repro.core.plan_tables import PlanTables

SIZES = (2, 4, 8, 12, 16)
# Scalar cost grows ~quadratically in tenants; cap its reps to keep the
# sweep short while the batched side gets enough reps for stable numbers,
# and skip the scalar reference entirely on the huge mixes.
BATCH_REPS = 15
SCALAR_REPS = 4
SCALAR_MAX_N = 16
ROUNDS = 3
# Rate drift applied between the incumbent plan and the re-planned mix:
# alternating +20% / -15%, the magnitude one 30 s controller period sees.
DRIFT = (1.20, 0.85)


def _mix(n: int):
    names = [PAPER_MODEL_NAMES[i % len(PAPER_MODEL_NAMES)] for i in range(n)]
    profs = [paper_profile(name) for name in names]
    rates = full_tpu_rates_for_utilization(profs, 0.5)
    return tenants(profs, rates)


def _drifted(ts):
    return tenants(
        [t.profile for t in ts],
        [t.rate * DRIFT[i % len(DRIFT)] for i, t in enumerate(ts)],
    )


def _best_of(fn, reps: int, rounds: int = ROUNDS) -> float:
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def run(sizes=SIZES) -> list[Row]:
    rows: list[Row] = []
    for n in sizes:
        ts = _mix(n)
        k_max = max(HW.cpu.n_cores, n)
        tables = PlanTables.for_tenants(ts, HW, k_max)
        # Identity first: the speedup claim only counts if plans agree.
        plan_b, obj_b = hill_climb(ts, HW, k_max, batch=True, tables=tables)
        if n <= SCALAR_MAX_N:
            plan_s, _ = _hill_climb_scalar(ts, HW, k_max)
            identical = plan_b == plan_s
            t_scalar = _best_of(lambda: _hill_climb_scalar(ts, HW, k_max), SCALAR_REPS)
        else:
            identical, t_scalar = None, None

        # Serving-loop conditions: the controller holds the rate-free tables
        # across re-plans, so the batched timing includes only the rate-aware
        # rebuild + climb.  The scalar path has no reusable state.
        t_batch = _best_of(
            lambda: hill_climb(ts, HW, k_max, batch=True, tables=tables), BATCH_REPS
        )
        parts = [f"batch_ms={t_batch * 1e3:.2f}"]
        if t_scalar is not None:
            t_batch_cold = _best_of(
                lambda: hill_climb(ts, HW, k_max, batch=True), BATCH_REPS
            )
            parts += [
                f"speedup={t_scalar / t_batch:.1f}x",
                f"cold={t_scalar / t_batch_cold:.1f}x",
                f"scalar_ms={t_scalar * 1e3:.2f}",
                f"identical_plans={identical}",
            ]

        # Incremental re-plan: rates drift one controller period, the climb
        # warm-starts from the incumbent plan with delta evaluation.
        ts2 = _drifted(ts)
        plan_c, obj_c = hill_climb(ts2, HW, k_max, batch=True, tables=tables)
        plan_w, obj_w = hill_climb(
            ts2, HW, k_max, batch=True, tables=tables, init_plan=plan_b
        )
        t_replan_cold = _best_of(
            lambda: hill_climb(ts2, HW, k_max, batch=True, tables=tables), BATCH_REPS
        )
        t_replan_warm = _best_of(
            lambda: hill_climb(
                ts2, HW, k_max, batch=True, tables=tables, init_plan=plan_b
            ),
            BATCH_REPS,
        )
        warm_ok = plan_w == plan_c or obj_w <= obj_c * (1.0 + 1e-9)
        parts += [
            f"replan_cold_ms={t_replan_cold * 1e3:.2f}",
            f"replan_warm_ms={t_replan_warm * 1e3:.2f}",
            f"warm_speedup={t_replan_cold / t_replan_warm:.1f}x",
            f"warm_ties_or_beats_cold={warm_ok}",
        ]
        rows.append(Row(f"alg_scaling/n{n}", t_batch * 1e6, " ".join(parts)))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--tenants",
        type=lambda s: tuple(int(x) for x in s.split(",")),
        default=SIZES,
        help="comma-separated mix sizes to sweep (default: %(default)s)",
    )
    args = ap.parse_args(argv)
    for r in run(args.tenants):
        print(r.csv())


if __name__ == "__main__":
    main()
