"""TPU scheduling disciplines vs FCFS: the swap-amortization sweep.

Quantifies what the pluggable service discipline subsystem
(``repro.serving.scheduling``) buys on swap-heavy multi-tenant mixes: the
``swap_batch`` discipline serves runs of queued same-tenant requests so one
inter-model swap-in (Eq. 2's ``T_load``) amortizes over the run.  Each row
simulates the mix on the event-driven ground truth (``backend="des"``) and
reports the mean-latency reduction vs FCFS, the observed swap-in (miss)
rate, and the batch-amortized analytic prediction
(``queueing.swap_batch_amortization``) with its error -- the model is what
the planner co-optimizes over, so its accuracy on these rows is what makes
``hill_climb(discipline_space=...)`` trustworthy.

Mixes:

* ``swap2`` -- efficientnet + gpunet full-TPU at ~0.72 FCFS utilization:
  the Fig. 6 alpha ~ 0.5 thrashing pair and the headline amortization row
  (two tenants means deep per-tenant queues to batch from).
* ``thrash16`` -- 16 small-model tenants contending for SRAM.  Swap-heavy
  but per-tenant queues are shallow (16 ways to split the backlog), so the
  amortization win is honest-but-modest -- the regime where batching helps
  least while still never hurting.
* ``collab8`` -- the paper's collaborative regime: every resident prefix
  fits SRAM together, zero swap-ins.  The control row: all disciplines
  must price and serve it identically to FCFS (no regression when there is
  nothing to amortize).

Before anything is timed, the FCFS run is self-checked **bitwise** against
the frozen PR-3 DES snapshot (``benchmarks/des_baseline.py``) -- the
"non-FCFS disciplines are opt-in, FCFS stays pinned" invariant from
ROADMAP.md; a sweep whose baseline drifted from the reference would be
meaningless.

Usage:
    PYTHONPATH=src python -m benchmarks.scheduling [--smoke]
        [--duration SEC] [--seed N] [--out BENCH_scheduling.json]
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks.common import HW, Row
from benchmarks.des_baseline import baseline_simulate
from repro.configs.paper_models import paper_profile
from repro.core import latency
from repro.core.planner import (
    FCFS,
    DisciplineSpec,
    Plan,
    TenantSpec,
    prefix_service_time,
    validate_plan,
)
from repro.serving.simulator import simulate
from repro.serving.workload import poisson_trace

BATCH_CAPS = (2, 4, 8, 16)


def _equal_load_rates(profiles, plan, rho_base: float) -> list[float]:
    """One shared per-tenant rate putting the swap-free TPU utilization at
    ``rho_base`` (swap-ins inflate the realized rho above it)."""
    s = [
        prefix_service_time(p, q, HW)
        for p, q in zip(profiles, plan.partition)
    ]
    return [rho_base / sum(s)] * len(profiles)


def _mixes() -> dict[str, tuple[list[TenantSpec], Plan]]:
    eff, gpu = paper_profile("efficientnet"), paper_profile("gpunet")
    sq, mb = paper_profile("squeezenet"), paper_profile("mobilenetv2")
    mn = paper_profile("mnasnet")

    swap_profiles = [eff, gpu]
    swap_plan = Plan((6, 5), (0, 0))

    thrash_profiles = [sq, mb, mn, eff] * 4
    thrash_plan = Plan(
        tuple(p.num_partition_points for p in thrash_profiles),
        (0,) * len(thrash_profiles),
    )

    collab_profiles = [sq] * 4 + [mb] * 4
    collab_plan = Plan(
        tuple([sq.num_partition_points] * 4 + [1] * 4),
        tuple([0] * 4 + [1] * 4),
    )

    mixes = {}
    for name, profiles, plan, rho in (
        ("swap2", swap_profiles, swap_plan, 0.55),
        ("thrash16", thrash_profiles, thrash_plan, 0.55),
        ("collab8", collab_profiles, collab_plan, 0.60),
    ):
        rates = _equal_load_rates(profiles, plan, rho)
        ts = [TenantSpec(p, r) for p, r in zip(profiles, rates)]
        validate_plan(plan, ts, HW.cpu.n_cores)
        mixes[name] = (ts, plan)
    return mixes


def _disciplines() -> list[tuple[str, DisciplineSpec]]:
    specs = [("fcfs", FCFS)]
    specs += [
        (f"swap_batch{c}", DisciplineSpec("swap_batch", batch_cap=c))
        for c in BATCH_CAPS
    ]
    return specs


def _self_check_fcfs(ts, plan, trace) -> None:
    """FCFS DES must be bitwise the frozen PR-3 snapshot before timing."""
    new = simulate(ts, plan, HW, trace, backend="des")
    old = baseline_simulate(ts, plan, HW, trace.to_requests(), backend="des")
    assert new.latencies == old.latencies, "fcfs diverged from des_baseline"
    assert new.misses == old.misses
    assert new.tpu_requests == old.tpu_requests
    assert new.tpu_busy == old.tpu_busy


def run_sweep(*, duration: float = 1500.0, seed: int = 0, check: bool = True) -> dict:
    rows: list[dict] = []
    for mix_name, (ts, plan) in _mixes().items():
        rates = [t.rate for t in ts]
        trace = poisson_trace(rates, duration, seed=seed)
        if check:
            # Short self-check trace: cheap, still thousands of events.
            _self_check_fcfs(ts, plan, trace[: min(len(trace), 5000)])
        fcfs_mean = None
        for disc_name, spec in _disciplines():
            p = Plan(plan.partition, plan.cores, spec)
            res = simulate(ts, p, HW, trace, backend="des")
            pred = latency.predict(ts, p, HW)
            obs = res.request_weighted_mean(rates)
            pm = pred.mean_latency(ts)
            if disc_name == "fcfs":
                fcfs_mean = obs
            miss = [
                res.observed_miss_rate(i) for i in range(len(ts))
            ]
            finite_miss = [m for m in miss if math.isfinite(m)]
            p99s = [res.p99(i) for i in range(len(ts))]
            rows.append(
                {
                    "mix": mix_name,
                    "discipline": disc_name,
                    "batch_cap": spec.batch_cap,
                    "n_requests": len(trace),
                    "mean_ms": obs * 1e3,
                    "worst_p99_ms": max(p99s) * 1e3,
                    "mean_miss_rate": (
                        sum(finite_miss) / len(finite_miss)
                        if finite_miss
                        else math.nan
                    ),
                    "reduction_vs_fcfs_pct": (
                        100.0 * (1.0 - obs / fcfs_mean) if fcfs_mean else 0.0
                    ),
                    "pred_mean_ms": pm * 1e3,
                    "pred_err_pct": 100.0 * (pm - obs) / obs,
                    "tpu_utilization": res.tpu_utilization,
                }
            )

    best = {}
    for r in rows:
        if r["mix"] == "swap2" and r["discipline"].startswith("swap_batch"):
            if not best or r["reduction_vs_fcfs_pct"] > best["reduction_vs_fcfs_pct"]:
                best = r
    headline = {
        "swap2_best_reduction_pct": best.get("reduction_vs_fcfs_pct"),
        "swap2_best_discipline": best.get("discipline"),
        "swap2_best_pred_err_pct": best.get("pred_err_pct"),
    }
    return {
        "benchmark": "scheduling",
        "duration": duration,
        "seed": seed,
        "headline": headline,
        "rows": rows,
    }


def _rows_of(report: dict) -> list[Row]:
    return [
        Row(
            f"scheduling/{r['mix']}/{r['discipline']}",
            r["mean_ms"] * 1e3,
            f"vs_fcfs_pct={r['reduction_vs_fcfs_pct']:.1f};"
            f"miss={r['mean_miss_rate']:.3f};"
            f"pred_err_pct={r['pred_err_pct']:.1f};"
            f"p99_ms={r['worst_p99_ms']:.1f}",
        )
        for r in report["rows"]
    ]


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(run_sweep(duration=200.0))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short traces: CI sanity (self-check + shape), not a record",
    )
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_scheduling.json")
    args = ap.parse_args()
    duration = args.duration if args.duration is not None else (
        200.0 if args.smoke else 1500.0
    )
    report = run_sweep(duration=duration, seed=args.seed)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    h = report["headline"]
    if h.get("swap2_best_reduction_pct") is not None:
        print(
            f"# headline swap2: {h['swap2_best_discipline']} cuts mean "
            f"latency {h['swap2_best_reduction_pct']:.1f}% vs fcfs "
            f"(model err {h['swap2_best_pred_err_pct']:+.1f}%)"
        )
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
