"""Simulation-core throughput: requests/sec for every serving backend.

Measures the high-throughput simulation core (columnar traces + vectorized
Lindley stepper + optimized DES hot loop) against the *pre-PR
implementations* -- the scalar per-request stepper driver and the frozen
PR-3 DES snapshot in ``benchmarks/des_baseline.py`` -- across trace sizes
and tenant counts, and records the numbers in ``BENCH_sim_throughput.json``
to start the perf trajectory.

Mixes:

* ``collab8`` -- 8 tenants in the paper's collaborative regime: 4x
  squeezenet full-TPU + 4x mobilenetv2 with a small TPU prefix and a
  1-core CPU suffix.  All resident prefixes share SRAM without eviction,
  so the stepper fast path runs fully vectorized (first-touch miss
  accounting).  This is the acceptance row: >=10x stepper and >=3x DES
  at 1M requests.
* ``swap2`` -- efficientnet + gpunet full-TPU: the swap-thrashing pair
  (Fig. 6's alpha regime).  Misses replay through the run-compressed LRU
  loop, the fast path's worst case.
* ``thrash16`` -- 16 small-model tenants contending for SRAM (capped at
  100k requests to keep the run short).

Every timed fast/baseline pair is first cross-checked for equal results on
the smallest size -- a throughput number for a simulator that diverged from
its reference would be meaningless.

Usage:
    PYTHONPATH=src python -m benchmarks.sim_throughput [--smoke]
        [--sizes 10000,100000,1000000] [--out BENCH_sim_throughput.json]
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from benchmarks.common import HW, Row
from benchmarks.des_baseline import baseline_simulate
from repro.configs.paper_models import paper_profile
from repro.core.planner import Plan, TenantSpec, validate_plan
from repro.serving.simulator import simulate
from repro.serving.workload import Trace, poisson_trace


def _mixes() -> dict[str, tuple[list[TenantSpec], Plan, int | None]]:
    """name -> (tenants, plan, size cap)."""
    sq, mb = paper_profile("squeezenet"), paper_profile("mobilenetv2")
    eff, gpu = paper_profile("efficientnet"), paper_profile("gpunet")
    mn = paper_profile("mnasnet")

    collab_profiles = [sq] * 4 + [mb] * 4
    collab = Plan(
        tuple([sq.num_partition_points] * 4 + [1] * 4),
        tuple([0] * 4 + [1] * 4),
    )
    thrash_profiles = [sq, mb, mn, eff] * 4
    thrash = Plan(
        tuple(p.num_partition_points for p in thrash_profiles),
        tuple(0 for _ in thrash_profiles),
    )
    mixes = {
        "collab8": ([TenantSpec(p, 1.0) for p in collab_profiles], collab, None),
        "swap2": ([TenantSpec(p, 1.0) for p in (eff, gpu)], Plan((6, 5), (0, 0)), None),
        "thrash16": ([TenantSpec(p, 1.0) for p in thrash_profiles], thrash, 100_000),
    }
    for ts, plan, _ in mixes.values():
        validate_plan(plan, ts, HW.cpu.n_cores)
    return mixes


def _trace_for(n_tenants: int, size: int, seed: int) -> Trace:
    # Per-tenant rate 25/s; duration set so the merged trace has ~size rows.
    rate = 25.0
    duration = size / (rate * n_tenants)
    return poisson_trace([rate] * n_tenants, duration, seed=seed)


def _same(a, b) -> bool:
    return (
        all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(a.latencies, b.latencies)
        )
        and a.misses == b.misses
        and a.tpu_requests == b.tpu_requests
    )


def measure(*, sizes: list[int], seed: int = 0, check: bool = True, reps: int = 2) -> dict:
    rows: list[dict] = []
    for mix_name, (ts, plan, cap) in _mixes().items():
        mix_sizes = [s for s in sizes if cap is None or s <= cap]
        if not mix_sizes:
            continue
        if check:
            # Results must match before their timings may be compared.
            check_trace = _trace_for(len(ts), min(mix_sizes), seed)
            reqs0 = check_trace.to_requests()
            assert _same(
                simulate(ts, plan, HW, check_trace),
                baseline_simulate(ts, plan, HW, reqs0, backend="stepper"),
            ), f"{mix_name}: fast stepper diverged from scalar baseline"
            assert _same(
                simulate(ts, plan, HW, check_trace, backend="des"),
                baseline_simulate(ts, plan, HW, reqs0, backend="des"),
            ), f"{mix_name}: optimized DES diverged from frozen baseline"
        for size in mix_sizes:
            trace = _trace_for(len(ts), size, seed)
            reqs = trace.to_requests()  # pre-PR callers held list[Request]
            n = len(trace)
            timed = [
                ("stepper", lambda: simulate(ts, plan, HW, trace)),
                (
                    "stepper_baseline",
                    lambda: baseline_simulate(
                        ts, plan, HW, reqs, backend="stepper"
                    ),
                ),
                ("des", lambda: simulate(ts, plan, HW, trace, backend="des")),
                (
                    "des_baseline",
                    lambda: baseline_simulate(ts, plan, HW, reqs, backend="des"),
                ),
            ]
            for backend, fn in timed:
                dt = math.inf
                for _ in range(reps):
                    t0 = time.perf_counter()
                    fn()
                    dt = min(dt, time.perf_counter() - t0)
                rows.append(
                    {
                        "mix": mix_name,
                        "backend": backend,
                        "tenants": len(ts),
                        "n_requests": n,
                        "seconds": dt,
                        "requests_per_sec": n / dt,
                    }
                )

    def largest(mix: str, backend: str) -> dict | None:
        sel = sorted(
            (r for r in rows if r["mix"] == mix and r["backend"] == backend),
            key=lambda r: r["n_requests"],
        )
        return sel[-1] if sel else None

    # The speedups the acceptance thresholds are defined on hold at 1M
    # requests (fixed vectorization costs amortize with size), so the
    # headline always names the trace size it was computed at -- a smoke
    # run's 10k-row headline must not be misread against the 1M criteria.
    headline = {}
    s_new, s_old = largest("collab8", "stepper"), largest(
        "collab8", "stepper_baseline"
    )
    d_new, d_old = largest("collab8", "des"), largest("collab8", "des_baseline")
    if s_new and s_old:
        headline["n_requests"] = s_new["n_requests"]
        headline["stepper_speedup"] = (
            s_new["requests_per_sec"] / s_old["requests_per_sec"]
        )
    if d_new and d_old:
        headline["des_speedup"] = (
            d_new["requests_per_sec"] / d_old["requests_per_sec"]
        )
    return {
        "benchmark": "sim_throughput",
        "sizes": sizes,
        "seed": seed,
        "reps": reps,
        "headline": headline,
        "rows": rows,
    }


def _rows_of(report: dict) -> list[Row]:
    return [
        Row(
            f"sim_throughput/{r['mix']}/{r['backend']}/n{r['n_requests']}",
            1e6 * r["seconds"] / r["n_requests"],
            f"reqs_per_sec={r['requests_per_sec']:.0f}",
        )
        for r in report["rows"]
    ]


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(measure(sizes=[10_000], reps=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="10k-request traces only: CI sanity, not a perf record",
    )
    ap.add_argument(
        "--sizes",
        type=lambda s: [int(x) for x in s.split(",")],
        default=None,
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--reps",
        type=int,
        default=None,
        help="best-of-N timing per cell (default 2; 1 in --smoke)",
    )
    ap.add_argument("--out", default="BENCH_sim_throughput.json")
    args = ap.parse_args()
    sizes = args.sizes if args.sizes is not None else (
        [10_000] if args.smoke else [10_000, 100_000, 1_000_000]
    )
    reps = args.reps if args.reps is not None else (1 if args.smoke else 2)
    report = measure(sizes=sizes, seed=args.seed, reps=reps)
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    head = dict(report["headline"])
    n_head = head.pop("n_requests", None)
    for key, v in head.items():
        print(f"# headline {key}: {v:.2f}x (at n={n_head})")
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
