"""Fig. 3 reproduction: per-segment TPU vs CPU performance (InceptionV4).

Paper claim: early segments see large TPU gains; the last three segments
are CPU-comparable -- the opportunity for collaborative inference.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs.paper_models import paper_profile


def run() -> list[Row]:
    rows = []
    prof = paper_profile("inceptionv4")
    for i, seg in enumerate(prof.segments):
        speedup = seg.cpu_time_1core / seg.tpu_time
        rows.append(
            Row(
                name=f"fig3/inceptionv4/seg{i}",
                us_per_call=seg.tpu_time * 1e6,
                derived=f"tpu_speedup={speedup:.1f}x;cpu_us={seg.cpu_time_1core*1e6:.0f}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
