"""JAX Monte-Carlo replica-sweep throughput vs the NumPy stepper.

The workload is the paper's Monte-Carlo robustness sweep: R service-jitter
replicas of one collab8 arrival trace (shared arrival order, per-model
service scales -- measurement-uncertainty MC over the profiled service
times).  Three engines price it:

* ``jax_replicas`` -- ``JaxStepper.run_trace_replicas``: routing, miss
  replay, and enqueue clocks hoisted once, all R busy-period recurrences
  resolved in a handful of fused jitted scans (float32, statistical-
  equivalence contract);
* ``numpy_replicas`` -- the vectorized NumPy stepper (``run_trace``)
  looped over replicas: the bitwise-pinned fast path, paying the full
  per-replica pipeline R times;
* ``numpy_scalar_replicas`` -- the scalar per-request reference driver
  (``vectorize=False``), the seed semantics baseline.  Timed on one
  replica and extrapolated x R (its per-replica cost is constant); the
  row says so.

Self-check before timing, as in ``sim_throughput``: the replica engine's
per-replica per-model mean latencies must match per-replica NumPy
``simulate`` runs within float32 tolerance (and integer observables
exactly) before any timing is recorded.

Honesty note (the recorded ``BENCH_jax_throughput.json``): on a CPU-only
jax install (``platform: "cpu"``, the CI fallback) the vectorized-stepper
speedup lands around 3-4x on a single core -- both engines are memory-
bound on the same recurrences, and XLA:CPU buys no extra parallelism.
The ISSUE's >= 5x target presumes an accelerator-backed jax; the scalar
reference comparison (the same baseline the sim_throughput headline is
defined against) clears it by an order of magnitude either way.  The
headline records both, never a blended number.
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from benchmarks.common import HW, Row
from benchmarks.sim_throughput import _mixes
from repro.serving.simulator import make_backend, simulate
from repro.serving.workload import Trace

# Per-tenant offered rates: squeezenet's 56 ms full-TPU service saturates
# the collab8 mix at the symmetric sim_throughput rates, so the MC sweep
# runs the asymmetric split that lands at ~0.6 TPU utilization -- queueing
# is live (delays matter) but stable (the sweep prices a servable system).
_RATES = [2.4] * 4 + [15.0] * 4


def _collab8():
    ts, plan, _ = _mixes()["collab8"]
    return ts, plan


def _trace_for(size: int, seed: int) -> Trace:
    rng = np.random.default_rng(seed)
    lam = float(sum(_RATES))
    arr = np.cumsum(rng.exponential(1.0 / lam, size))
    mi = rng.choice(
        len(_RATES), size=size, p=np.asarray(_RATES) / lam
    ).astype(np.int64)
    return Trace(mi, arr)


def _scales_for(n_replicas: int, n_models: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    return rng.uniform(0.8, 1.25, size=(n_replicas, n_models))


def _self_check(ts, plan, seed: int) -> None:
    """Statistical equivalence on a small instance, before any timing."""
    profs = [t.profile for t in ts]
    trace = _trace_for(5_000, seed)
    scales = _scales_for(3, len(profs), seed)
    sim = make_backend("jax", profs, plan, HW)
    stats = sim.run_trace_replicas(trace, scales)
    for r in range(scales.shape[0]):
        tr = Trace(trace.model_idx, trace.arrival, scales[r][trace.model_idx])
        ref = simulate(ts, plan, HW, tr, warmup_frac=0.0)
        assert list(stats.misses) == ref.misses, "miss pattern diverged"
        for m in range(len(profs)):
            assert stats.counts[m] == len(ref.latencies[m])
            rm = ref.mean_latency(m)
            if not abs(stats.mean_latency[r, m] - rm) <= 1e-3 * rm + 1e-9:
                raise AssertionError(
                    f"replica {r} model {m}: jax mean "
                    f"{stats.mean_latency[r, m]} vs numpy {rm}"
                )


def measure(
    *,
    sizes: list[int],
    n_replicas: int = 32,
    seed: int = 0,
    check: bool = True,
    reps: int = 2,
) -> dict:
    import jax

    ts, plan = _collab8()
    profs = [t.profile for t in ts]
    if check:
        _self_check(ts, plan, seed)

    rows: list[dict] = []
    for size in sizes:
        trace = _trace_for(size, seed)
        scales = _scales_for(n_replicas, len(profs), seed)
        mi = trace.model_idx

        sim = make_backend("jax", profs, plan, HW)
        t0 = time.perf_counter()
        sim.run_trace_replicas(trace, scales)  # compile + first run
        first = time.perf_counter() - t0
        dt_jax = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            sim.run_trace_replicas(trace, scales)
            dt_jax = min(dt_jax, time.perf_counter() - t0)

        dt_np = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            for r in range(n_replicas):
                tr = Trace(mi, trace.arrival, scales[r][mi])
                simulate(ts, plan, HW, tr, warmup_frac=0.0)
            dt_np = min(dt_np, time.perf_counter() - t0)

        # Scalar reference: one replica, extrapolated (constant per-replica
        # cost; running all R at 1M rows would take minutes for no extra
        # information).
        tr0 = Trace(mi, trace.arrival, scales[0][mi])
        dt_sc1 = math.inf
        for _ in range(reps):
            t0 = time.perf_counter()
            simulate(ts, plan, HW, tr0, warmup_frac=0.0, vectorize=False)
            dt_sc1 = min(dt_sc1, time.perf_counter() - t0)
        dt_sc = dt_sc1 * n_replicas

        for engine, dt, note in (
            ("jax_replicas", dt_jax, f"compile_first_run={first:.3f}s"),
            ("numpy_replicas", dt_np, "vectorized run_trace per replica"),
            (
                "numpy_scalar_replicas",
                dt_sc,
                f"extrapolated: one replica timed ({dt_sc1:.3f}s) x R",
            ),
        ):
            rows.append(
                {
                    "mix": "collab8",
                    "engine": engine,
                    "n_requests": size,
                    "n_replicas": n_replicas,
                    "seconds": dt,
                    "replica_requests_per_sec": size * n_replicas / dt,
                    "note": note,
                }
            )

    def largest(engine: str) -> dict | None:
        sel = sorted(
            (r for r in rows if r["engine"] == engine),
            key=lambda r: r["n_requests"],
        )
        return sel[-1] if sel else None

    jx, vec, sc = (
        largest("jax_replicas"),
        largest("numpy_replicas"),
        largest("numpy_scalar_replicas"),
    )
    headline: dict = {}
    if jx and vec:
        headline["n_requests"] = jx["n_requests"]
        headline["n_replicas"] = jx["n_replicas"]
        headline["speedup_vs_vectorized_stepper"] = (
            vec["seconds"] / jx["seconds"]
        )
    if jx and sc:
        headline["speedup_vs_scalar_stepper"] = sc["seconds"] / jx["seconds"]

    platform = jax.default_backend()
    return {
        "benchmark": "jax_throughput",
        "sizes": sizes,
        "n_replicas": n_replicas,
        "seed": seed,
        "reps": reps,
        "equivalence_checked": bool(check),
        "platform": platform,
        "cpu_fallback": platform == "cpu",
        "note": (
            "speedup_vs_vectorized_stepper is the like-for-like engine "
            "comparison; on the cpu jax fallback it sits well below the "
            "accelerator target (see benchmarks/README.md). "
            "speedup_vs_scalar_stepper is against the seed scalar "
            "reference driver."
        ),
        "headline": headline,
        "rows": rows,
    }


def _rows_of(report: dict) -> list[Row]:
    return [
        Row(
            f"jax_throughput/{r['mix']}/{r['engine']}"
            f"/n{r['n_requests']}xR{r['n_replicas']}",
            1e6 * r["seconds"] / (r["n_requests"] * r["n_replicas"]),
            f"replica_reqs_per_sec={r['replica_requests_per_sec']:.0f}",
        )
        for r in report["rows"]
    ]


def run() -> list[Row]:
    """benchmarks.run harness entry point: the smoke-sized sweep."""
    return _rows_of(measure(sizes=[10_000], n_replicas=8, reps=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="10k-request traces, R=8: CI sanity, not a perf record",
    )
    ap.add_argument(
        "--sizes",
        type=lambda s: [int(x) for x in s.split(",")],
        default=None,
    )
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_jax_throughput.json")
    args = ap.parse_args()
    sizes = args.sizes if args.sizes is not None else (
        [10_000] if args.smoke else [100_000, 1_000_000]
    )
    n_replicas = args.replicas if args.replicas is not None else (
        8 if args.smoke else 32
    )
    reps = args.reps if args.reps is not None else (1 if args.smoke else 2)
    report = measure(
        sizes=sizes, n_replicas=n_replicas, seed=args.seed, reps=reps
    )
    report["smoke"] = bool(args.smoke)
    print("name,us_per_call,derived")
    for row in _rows_of(report):
        print(row.csv())
    head = dict(report["headline"])
    n_head = head.pop("n_requests", None)
    r_head = head.pop("n_replicas", None)
    for key, v in head.items():
        print(f"# headline {key}: {v:.2f}x (at n={n_head}, R={r_head}, "
              f"platform={report['platform']})")
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")


if __name__ == "__main__":
    main()
