"""Shared helpers for the paper-figure benchmarks.

Output convention (benchmarks/run.py): CSV rows ``name,us_per_call,derived``
where ``us_per_call`` is the scenario's mean end-to-end latency in
microseconds (what the paper's figures plot) and ``derived`` is the
figure's headline metric (MAPE, swap share, latency reduction, ...).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

from repro.core.planner import ModelProfile, Plan, TenantSpec, prefix_service_time
from repro.hw.specs import EDGE_TPU_PLATFORM

HW = EDGE_TPU_PLATFORM
K_MAX = HW.cpu.n_cores


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def full_tpu_rates_for_utilization(
    profiles: Sequence[ModelProfile], rho: float
) -> list[float]:
    """Per-model rates so each contributes rho/n TPU load at full-TPU
    execution (the paper: 'each model contributes equally to the load')."""
    n = len(profiles)
    rates = []
    for prof in profiles:
        s = prefix_service_time(prof, prof.num_partition_points, HW)
        rates.append(rho / n / s)
    return rates


def tenants(profiles: Sequence[ModelProfile], rates: Sequence[float]) -> list[TenantSpec]:
    return [TenantSpec(p, r) for p, r in zip(profiles, rates)]


def mape(pred: Sequence[float], obs: Sequence[float]) -> float:
    """Mean absolute percentage error over comparable pairs.

    Pairs with a non-positive or non-finite observation, or a non-finite
    prediction (an unstable-queue ``inf``/``nan``), carry no comparable
    error and are skipped; ``nan`` when no pair survives (e.g. the analytic
    model predicts instability everywhere -- see benchmarks/README.md).
    """
    pairs = [
        (p, o)
        for p, o in zip(pred, obs)
        if o > 0 and math.isfinite(p) and math.isfinite(o)
    ]
    if not pairs:
        return math.nan
    return 100.0 * sum(abs(p - o) / o for p, o in pairs) / len(pairs)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
